"""The tracing layer: span recording (nesting, cross-thread, ambient
context), the zero-cost disabled path, ring-buffer bounds, the Chrome
trace-event / Prometheus exporters, the fleet event taxonomy and its
ordering across a steal + drain, per-slab streaming spans, and the
phase-seconds plumbing through ServeMetrics / merge_metrics."""

import json
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core import phantoms
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.operator import CTOperator
from repro.core.plan import plan as plan_execution
from repro.core.splitting import MemoryModel
from repro.obs.trace import _NULL, Tracer, chrome_trace
from repro.serve import (MultiPodScheduler, Pod, PodSpec, ReconJob,
                         Scheduler, ServeMetrics, merge_metrics)

GEO = ConeGeometry.nice(16)
ANGLES = circular_angles(12)
PROJ = phantoms.sphere_projection_analytic(GEO, ANGLES)

KIB = 1024


def _mem(kib, frac=1.0):
    return MemoryModel(device_bytes=kib * KIB, usable_fraction=frac)


def _job(alg="cgls", n_iter=2, **kw):
    return ReconJob(alg, GEO, ANGLES, PROJ, n_iter=n_iter, **kw)


@pytest.fixture
def tracer():
    """The process tracer, enabled and empty; restored disabled+empty."""
    t = obs.get_tracer()
    t.clear()
    t.enable()
    yield t
    t.disable()
    t.clear()


# --------------------------------------------------------------------------
# recorder semantics
# --------------------------------------------------------------------------

def test_span_nesting_records_both_with_attrs(tracer):
    with obs.span("outer", "compute", job="j1"):
        with obs.span("inner", "h2d", slab=3):
            pass
    spans = tracer.spans()
    assert [s.name for s in spans] == ["inner", "outer"]   # close order
    inner, outer = spans
    assert inner.cat == "h2d" and inner.attrs == {"slab": 3}
    assert outer.cat == "compute" and outer.attrs == {"job": "j1"}
    assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1   # true nesting
    assert all(s.duration >= 0 for s in spans)


def test_cross_thread_begin_end_attributed_to_opening_thread(tracer):
    h = obs.begin("init", "compile", job="j2")
    opener = threading.get_ident()

    def closer():
        obs.end(h, extra=1)

    t = threading.Thread(target=closer)
    t.start()
    t.join()
    (s,) = tracer.spans()
    assert s.thread == opener          # not the closing thread
    assert s.attrs == {"job": "j2", "extra": 1}


def test_abandoned_handle_records_nothing(tracer):
    obs.begin("never-closed", "compute")
    assert tracer.spans() == []
    assert tracer.phase_seconds() == {}


def test_clear_orphans_open_handles(tracer):
    h = obs.begin("stale", "compute")
    tracer.clear()
    obs.end(h)                          # generation mismatch: no-op
    assert tracer.spans() == []


def test_context_merges_ambient_attrs_and_explicit_wins(tracer):
    with obs.context(job="j3", pod="p0", device=1):
        with obs.span("work", "compute", device=7):
            pass
        obs.event("mark")
    with obs.span("outside", "compute"):
        pass
    work = tracer.spans(name="work")[0]
    assert work.attrs == {"job": "j3", "pod": "p0", "device": 7}
    (ev,) = tracer.events()
    assert ev.attrs == {"job": "j3", "pod": "p0", "device": 1}
    assert tracer.spans(name="outside")[0].attrs == {}   # ctx restored


def test_ring_buffer_bounds_and_counts_drops():
    t = Tracer(capacity=8, enabled=True)
    for i in range(20):
        with t.span(f"s{i}", "compute"):
            pass
    assert len(t.records()) == 8
    assert t.dropped() == 12
    # aggregate counters keep running past evictions
    assert sum(1 for _ in t.spans("compute")) == 8
    assert t.prometheus().count('repro_spans_total{cat="compute"} 20') == 1


def test_threaded_hammer_loses_nothing():
    t = Tracer(capacity=1 << 14, enabled=True)
    n_threads, per_thread = 8, 200

    def work(k):
        for i in range(per_thread):
            with t.span("w", "compute", thread=k, i=i):
                pass
            t.event("tick", thread=k)
            t.incr("hits")

    threads = [threading.Thread(target=work, args=(k,))
               for k in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    total = n_threads * per_thread
    assert len(t.spans("compute")) == total
    assert len(t.events("tick")) == total
    assert t.counters()["hits"] == total
    assert t.dropped() == 0
    seqs = [r.seq for r in t.records()]
    assert len(set(seqs)) == len(seqs)              # unique, no torn writes


def test_phase_seconds_global_and_per_thread(tracer):
    def worker():
        with obs.span("w", "h2d"):
            time.sleep(0.01)

    t = threading.Thread(target=worker)
    t.start()
    t.join()
    with obs.span("m", "compute"):
        time.sleep(0.01)
    phases = tracer.phase_seconds()
    assert phases["h2d"] >= 0.01 and phases["compute"] >= 0.01
    # the calling thread's view excludes the worker's h2d time
    mine = tracer.thread_phase_seconds()
    assert "compute" in mine and "h2d" not in mine


# --------------------------------------------------------------------------
# disabled path: zero cost, shared no-ops
# --------------------------------------------------------------------------

def test_disabled_tracer_records_nothing_and_returns_singletons():
    t = obs.get_tracer()
    assert not t.enabled and not obs.enabled()
    assert obs.span("x", "h2d") is _NULL
    assert obs.context(job="j") is _NULL
    assert obs.begin("x") is None
    obs.end(None)
    obs.event("submit")
    obs.incr("c")
    with obs.span("y", "compute"):
        pass
    assert t.records() == []
    assert t.phase_seconds() == {}
    assert t.counters() == {}


def test_env_var_enables_at_construction(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE", "1")
    assert Tracer().enabled
    monkeypatch.setenv("REPRO_TRACE", "0")
    assert not Tracer().enabled
    monkeypatch.delenv("REPRO_TRACE")
    assert not Tracer().enabled


# --------------------------------------------------------------------------
# exporters
# --------------------------------------------------------------------------

def test_chrome_trace_schema_tracks_and_rebase(tracer):
    with obs.context(pod="p0"):
        with obs.span("stage", "h2d", slab=0, device=0):
            pass
        with obs.span("fp_slab", "compute", slab=0, device=1):
            pass
    with obs.span("untracked", "compute"):       # no pod/device attrs
        pass
    obs.fleet_event("submit", job="j1", pod="p0")
    doc = tracer.chrome_trace()
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    metas = [e for e in evs if e["ph"] == "M"]
    instants = [e for e in evs if e["ph"] == "i"]
    assert len(xs) == 3 and len(instants) == 1
    for e in xs:
        assert {"name", "cat", "ts", "dur", "pid", "tid", "args"} <= set(e)
        assert e["ts"] >= 0 and e["dur"] >= 0    # rebased to run start
    assert instants[0]["s"] == "t"
    # process per pod, thread track per device
    procs = {m["args"]["name"] for m in metas if m["name"] == "process_name"}
    tracks = {m["args"]["name"] for m in metas if m["name"] == "thread_name"}
    assert procs == {"p0", "proc"}
    assert {"device0", "device1"} <= tracks
    # the pod-attributed spans land on the pod's pid
    pod_pid = next(m["pid"] for m in metas
                   if m["name"] == "process_name"
                   and m["args"]["name"] == "p0")
    assert all(e["pid"] == pod_pid for e in xs if e["args"].get("device")
               is not None)
    json.dumps(doc)                              # serializable end to end


def test_chrome_trace_coerces_non_json_attrs(tracer):
    with obs.span("s", "compute", count=np.int64(3), arr=np.float32(1.5),
                  obj=object()):
        pass
    doc = chrome_trace(tracer.records())
    (x,) = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert x["args"]["count"] == 3 and x["args"]["arr"] == 1.5
    assert isinstance(x["args"]["obj"], str)
    json.dumps(doc)


def test_prometheus_text_format(tracer):
    with obs.span("s", "h2d"):
        pass
    obs.fleet_event("submit", job="j1")
    obs.incr("dispatch_hits", 3)
    text = tracer.prometheus()
    assert text.endswith("\n")
    assert 'repro_phase_seconds_total{phase="h2d"} ' in text
    assert 'repro_spans_total{cat="h2d"} 1' in text
    assert 'repro_events_total{kind="submit"} 1' in text
    assert "repro_dispatch_hits_total 3" in text
    assert "repro_trace_dropped_records 0" in text
    for line in text.splitlines():
        assert line.startswith(("#", "repro_"))


def test_validate_trace_tool_accepts_real_trace(tracer, tmp_path):
    with obs.context(pod="p0", device=0):
        for cat in ("h2d", "compute", "d2h"):
            with obs.span(cat, cat, slab=0):
                pass
    path = str(tmp_path / "t.json")
    tracer.write_chrome_trace(path)
    proc = subprocess.run(
        [sys.executable, "tools/validate_trace.py", path,
         "--require-phases"],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "TRACE OK" in proc.stdout


def test_validate_trace_checks_prefetch_reduce_bytes(tracer, tmp_path):
    """The CommSchedule executors' prefetch/reduce spans are optional in
    a trace, but any that appear must be sized (the serving layer's
    bandwidth EMA is priced from their bytes args)."""
    with obs.context(pod="p0", device=0):
        for cat in ("h2d", "compute", "d2h"):
            with obs.span(cat, cat, slab=0):
                pass
        with obs.span("staging", "prefetch", slab=1, bytes=4096):
            pass
        with obs.span("reduce", "reduce", op="dist_fp", bytes=2048):
            pass
    path = str(tmp_path / "t.json")
    tracer.write_chrome_trace(path)
    proc = subprocess.run(
        [sys.executable, "tools/validate_trace.py", path,
         "--require-phases"],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "prefetch" in proc.stdout and "reduce" in proc.stdout

    # an unsized prefetch span is an instrumentation regression
    bad = {"traceEvents": [
        {"ph": "X", "name": "staging", "cat": "prefetch", "pid": 1,
         "tid": 1, "ts": 0.0, "dur": 1.0, "args": {"slab": 1}}]}
    bad_path = str(tmp_path / "bad.json")
    with open(bad_path, "w") as f:
        json.dump(bad, f)
    proc = subprocess.run(
        [sys.executable, "tools/validate_trace.py", bad_path],
        capture_output=True, text=True)
    assert proc.returncode == 1
    assert "bytes" in proc.stdout


# --------------------------------------------------------------------------
# fleet events
# --------------------------------------------------------------------------

def test_fleet_event_rejects_unknown_kind(tracer):
    with pytest.raises(ValueError, match="unknown fleet event"):
        obs.fleet_event("reboot", pod="p0")
    obs.fleet_event("submit", job="j1", pod="p0")   # known kinds fine
    assert [e.name for e in obs.fleet_event_log()] == ["submit"]


def test_fleet_event_log_filters(tracer):
    obs.fleet_event("submit", job="a", pod="p0")
    obs.fleet_event("submit", job="b", pod="p1")
    obs.fleet_event("complete", job="a", pod="p0")
    assert len(obs.fleet_event_log(job="a")) == 2
    assert len(obs.fleet_event_log(kind="submit")) == 2
    assert [e.attrs["job"] for e in obs.fleet_event_log(pod="p1")] == ["b"]


def test_scheduler_emits_lifecycle_events_in_order(tracer):
    sched = Scheduler(n_devices=1, memory=_mem(220), name="solo")
    jid = sched.submit(_job(n_iter=2))
    sched.run()
    names = [e.name for e in obs.fleet_event_log(job=jid)]
    assert names[0] == "submit"
    assert names[-1] == "complete"
    for kind in ("place", "admit", "step"):
        assert kind in names
    # ordering: submit < place < admit < first step < complete
    idx = {k: names.index(k) for k in ("submit", "place", "admit", "step",
                                       "complete")}
    assert idx["submit"] < idx["place"] < idx["admit"] < idx["step"] \
        < idx["complete"]
    admit = obs.fleet_event_log(job=jid, kind="admit")[0]
    assert admit.attrs["pod"] == "solo"
    assert admit.attrs["measured_s"] > 0
    steps = obs.fleet_event_log(job=jid, kind="step")
    assert len(steps) == 2 and all(e.attrs["measured_s"] > 0
                                   for e in steps)


def test_fleet_event_order_across_steal_and_drain(tracer, tmp_path):
    """A stolen job's event trail reads submit -> export (victim) ->
    import (thief) -> ... -> complete, strictly ordered; the scale-down
    style drain leaves a drain event after the parks."""
    pods = [Pod(PodSpec(f"p{i}", n_devices=1, memory=_mem(800)))
            for i in range(2)]
    mps = MultiPodScheduler(pods, transfer_dir=str(tmp_path / "xfer"))
    jids = [mps.submit(_job(n_iter=2), pod="p0") for _ in range(3)]
    moved = mps.steal_pass()
    assert moved, "imbalanced fleet must steal"
    for jid in moved:
        names = [e.name for e in obs.fleet_event_log(job=jid)]
        assert "export" in names and "import" in names
        assert names.index("export") < names.index("import")
        exp = obs.fleet_event_log(job=jid, kind="export")[0]
        imp = obs.fleet_event_log(job=jid, kind="import")[0]
        assert exp.attrs["pod"] == "p0" and imp.attrs["pod"] == "p1"
        seqs = [e.seq for e in obs.fleet_event_log(job=jid)]
        assert seqs == sorted(seqs)
    mps.run()
    for jid in jids:
        assert obs.fleet_event_log(job=jid, kind="complete")
    # drain: park everything left queued on a fresh scheduler
    sched = Scheduler(n_devices=1, memory=_mem(800), name="drainee")
    sched.submit(_job(n_iter=8))
    sched.admit()
    sched.drain(None, timeout=30)
    drains = obs.fleet_event_log(kind="drain")
    assert drains and drains[-1].attrs["pod"] == "drainee"
    parks = obs.fleet_event_log(kind="park")
    assert parks and parks[-1].seq < drains[-1].seq


def test_autoscaler_scale_events_logged(tracer, tmp_path):
    from repro.serve import Autoscaler, AutoscalePolicy
    mps = MultiPodScheduler(
        [Pod(PodSpec("seed", n_devices=1, memory=_mem(220)))],
        transfer_dir=str(tmp_path / "xfer"))
    asc = Autoscaler(mps, [PodSpec("burst", n_devices=1, memory=_mem(220))],
                     AutoscalePolicy(scale_up_backlog_seconds=0.5,
                                     scale_down_backlog_seconds=0.05,
                                     down_window_seconds=0.0,
                                     cooldown_seconds=0.0))
    ev = asc._scale_up(0.0, 9.9)
    assert ev is not None
    (up,) = obs.fleet_event_log(kind="scale-up")
    assert up.attrs["pod"] == ev.pod and up.attrs["n_pods"] == 2
    adds = obs.fleet_event_log(kind="pod-add")
    assert adds and adds[-1].attrs["pod"] == ev.pod


# --------------------------------------------------------------------------
# streaming + executor instrumentation
# --------------------------------------------------------------------------

def test_streaming_emits_per_slab_phase_spans(tracer):
    geo = ConeGeometry.nice(16)
    angles = circular_angles(8)
    mem = _mem(24)                      # too small for 16^3 whole: splits
    p = plan_execution(geo, len(angles), 1, mem)
    assert p.forward.n_slabs >= 2, "budget must force a split"
    op = CTOperator(geo, angles, mode="stream", memory=mem)
    vol = np.asarray(phantoms.shepp_logan(geo))
    proj = np.asarray(op.A(vol))
    np.asarray(op.At(proj))
    fp = tracer.spans(name="fp_slab")
    assert {s.attrs["slab"] for s in fp} == set(range(p.forward.n_slabs))
    assert all(s.cat == "compute" and "device" in s.attrs for s in fp)
    h2d = tracer.spans("h2d")
    assert {s.attrs.get("op") for s in h2d} == {"fp", "bp"}
    assert tracer.spans("d2h")
    bp = [s for s in tracer.spans("compute") if s.attrs.get("op") == "bp"]
    assert bp and all("chunk" in s.attrs and "slab" in s.attrs for s in bp)


def test_executor_phase_seconds_cover_step_wall_time(tracer):
    from repro.serve.executor import JobExecutor
    ex = JobExecutor(_job(n_iter=3), mode="plain", memory=_mem(800),
                     labels={"pod": "p0", "device": 0})
    ex.start()
    ex.take_phase_seconds()
    ex.step()                           # burn in compile effects
    ex.take_phase_seconds()
    t0 = time.monotonic()
    ex.step()
    dt = time.monotonic() - t0
    phases = ex.take_phase_seconds()
    assert "compute" in phases
    total = sum(phases.values())
    # the step span wraps ~the whole step; allow scheduling noise
    assert 0.5 * dt <= total <= 1.05 * dt, (phases, dt)
    # spans carry the ambient identity
    step_spans = [s for s in tracer.spans(name="step")
                  if s.attrs.get("pod") == "p0"]
    assert step_spans and all(s.attrs["device"] == 0 for s in step_spans)


def test_summary_reports_phase_seconds_and_disabled_is_empty(tracer):
    sched = Scheduler(n_devices=1, memory=_mem(800), name="s0")
    sched.submit(_job(n_iter=2))
    sched.run()
    s = sched.summary()
    assert s["phase_seconds"].get("compute", 0) > 0
    # phase attribution is within 10% of the measured step wall time
    # (plus init, which is attributed separately)
    busy = s["busy_seconds"]
    attributed = sum(v for k, v in s["phase_seconds"].items()
                     if k != "init")
    assert attributed <= 1.1 * (busy + s["phase_seconds"].get("init", 0))
    # disabled tracer -> empty phase dict (the zero-overhead default)
    obs.get_tracer().disable()
    sched2 = Scheduler(n_devices=1, memory=_mem(800))
    sched2.submit(_job(n_iter=1))
    sched2.run()
    assert sched2.summary()["phase_seconds"] == {}


def test_merge_metrics_phase_round_trip():
    a = ServeMetrics(phase_seconds={"h2d": 1.0, "compute": 2.0})
    b = ServeMetrics(phase_seconds={"compute": 3.0, "d2h": 0.5})
    m = merge_metrics([a, b])
    assert m.phase_seconds == {"h2d": 1.0, "compute": 5.0, "d2h": 0.5}
    assert m.summary()["phase_seconds"] == m.phase_seconds
    # and the round trip leaves the parts untouched
    assert a.phase_seconds == {"h2d": 1.0, "compute": 2.0}


def test_dispatch_counters_hit_and_miss(tracer):
    from repro.core.backend import get_backend
    tracer.clear()
    bk = get_backend("ref")
    geo = ConeGeometry.nice(16)
    bk.fp(geo, xdom=True)
    before = tracer.counters()
    bk.fp(geo, xdom=True)               # same key: a hit
    after = tracer.counters()
    assert after.get("dispatch_hits", 0) \
        == before.get("dispatch_hits", 0) + 1
    assert after.get("dispatch_misses", 0) \
        == before.get("dispatch_misses", 0)
