"""The cost-model feedback loop: calibration ledger math on synthetic
event streams (bias sign, percentile edges, drift firing and clearing),
memory watermark-vs-footprint margins, per-priority SLO accounting, the
live metrics endpoint round-trip, the ServeMetrics calibration gauges
through merge_metrics, the bench envelope schema, and the trajectory
gate (bench_track)."""

import json
import subprocess
import sys
import urllib.error
import urllib.request

import pytest

from repro import obs
from repro.core import phantoms
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.splitting import MemoryModel
from repro.obs.calibration import (CalibrationKey, CalibrationLedger,
                                   calibration_prometheus,
                                   memory_calibration)
from repro.obs.slo import slo_prometheus, slo_report
from repro.obs.trace import InstantEvent
from repro.serve import ReconJob, Scheduler, ServeMetrics, merge_metrics

GEO = ConeGeometry.nice(16)
ANGLES = circular_angles(12)
PROJ = phantoms.sphere_projection_analytic(GEO, ANGLES)

KIB = 1024


def _mem(kib, frac=1.0):
    return MemoryModel(device_bytes=kib * KIB, usable_fraction=frac)


def _job(alg="cgls", n_iter=2, **kw):
    return ReconJob(alg, GEO, ANGLES, PROJ, n_iter=n_iter, **kw)


@pytest.fixture
def tracer():
    """The process tracer, enabled and empty; restored disabled+empty."""
    t = obs.get_tracer()
    t.clear()
    t.enable()
    yield t
    t.disable()
    t.clear()


def _ev(kind, seq=0, **attrs):
    """A synthetic fleet event (ledger/SLO folding is pure attr math)."""
    return InstantEvent(name=kind, t=float(seq), thread=0, seq=seq,
                        attrs=attrs)


# --------------------------------------------------------------------------
# ledger math on synthetic streams
# --------------------------------------------------------------------------

def test_ledger_bias_sign_and_percentiles():
    # model says 1.0s; reality is 1.5, 1.1, 1.2, 3.0 -> optimistic model
    errs = (0.5, 0.1, 0.2, 2.0)
    events = [_ev("step", i, pod="p0", geo="16x16x16", alg="cgls",
                  backend="auto", modeled_s=1.0, measured_s=1.0 + e)
              for i, e in enumerate(errs)]
    led = CalibrationLedger.from_events(events)
    (st,) = led.entries()
    assert st.kind == "step" and st.samples == 4 and st.events == 4
    assert st.key == CalibrationKey("16x16x16", "cgls", "auto", "p0")
    assert st.bias_s == pytest.approx(sum(errs) / 4)     # positive bias
    assert st.abs_error_percentile(0) == pytest.approx(0.1)
    assert st.abs_error_percentile(100) == pytest.approx(2.0)
    assert st.abs_error_percentile(50) in (0.2, 0.5)     # nearest rank
    # pessimistic model -> negative bias
    led2 = CalibrationLedger.from_events(
        [_ev("admit", 0, pod="p1", modeled_s=2.0, measured_s=1.0)])
    (st2,) = led2.entries()
    assert st2.bias_s == pytest.approx(-1.0)


def test_ledger_one_sided_events_count_but_never_sample():
    events = [_ev("complete", 0, pod="p0", measured_s=3.0),
              _ev("scale-up", 1, pod="p1", modeled_s=0.5),
              _ev("migrate", 2, src="p0", dst="p1")]
    led = CalibrationLedger.from_events(events)
    assert led.events_by_kind() == {"complete": 1, "scale-up": 1,
                                    "migrate": 1}
    assert led.samples_by_kind() == {"complete": 0, "scale-up": 0,
                                     "migrate": 0}
    # totals still accumulate the known side
    by_kind = {st.kind: st for st in led.entries()}
    assert by_kind["complete"].measured_total_s == pytest.approx(3.0)
    assert by_kind["scale-up"].modeled_total_s == pytest.approx(0.5)
    # migrate keys by destination pod (where the job lands)
    assert by_kind["migrate"].key.pod == "p1"
    # and nothing ever drifts without two-sided samples
    assert led.stale_pods() == []


def test_ledger_drift_fires_then_clears():
    led = CalibrationLedger(drift_threshold=0.5, drift_min_samples=4)
    # 4 wildly wrong samples (100% relative error) -> drift fires
    for i in range(4):
        led.fold(_ev("step", i, pod="bad", modeled_s=1.0, measured_s=2.0))
    assert led.stale_pods() == ["bad"]
    (st,) = led.entries()
    assert st.drift and st.drift_ema > 0.5
    # a long run of accurate samples decays the EMA back under threshold
    for i in range(20):
        led.fold(_ev("step", 10 + i, pod="bad", modeled_s=1.0,
                     measured_s=1.0))
    (st,) = led.entries()
    assert not st.drift and st.drift_ema < 0.5
    assert led.stale_pods() == []


def test_ledger_min_samples_gate_holds_fire():
    led = CalibrationLedger(drift_threshold=0.5, drift_min_samples=4)
    for i in range(3):      # one short of the gate, 100% rel error
        led.fold(_ev("step", i, pod="p0", modeled_s=1.0, measured_s=2.0))
    assert led.stale_pods() == []


def test_ledger_groups_by_key_and_ignores_unknown_kinds():
    events = [_ev("step", 0, pod="p0", alg="cgls", modeled_s=1, measured_s=1),
              _ev("step", 1, pod="p0", alg="sirt", modeled_s=1, measured_s=1),
              _ev("step", 2, pod="p1", alg="cgls", modeled_s=1, measured_s=1),
              _ev("park", 3, pod="p0")]          # not a calibration kind
    led = CalibrationLedger.from_events(events)
    assert len(led.entries()) == 3
    assert led.events_by_kind() == {"step": 3}


# --------------------------------------------------------------------------
# memory calibration
# --------------------------------------------------------------------------

def test_memory_margin_watermark_vs_footprint(tracer):
    # staged transfers: high-water 512 on device0, 768 on device1
    for nbytes, dev in ((256, "device0"), (512, "device0"),
                        (768, "device1")):
        with obs.span("stage", "h2d", pod="p0", device=dev, bytes=nbytes):
            pass
    # modeled footprints committed at placement
    obs.fleet_event("place", job="j1", pod="p0", device="device0",
                    bytes=1024)
    obs.fleet_event("place", job="j2", pod="p0", device="device1",
                    bytes=512)
    margins = {(m.pod, m.device): m for m in memory_calibration()}
    safe = margins[("p0", "device0")]
    assert safe.measured_bytes == 512 and safe.modeled_bytes == 1024
    assert safe.margin == pytest.approx(2.0)
    risky = margins[("p0", "device1")]
    assert risky.margin == pytest.approx(512 / 768)      # < 1: OOM risk
    assert risky.as_dict()["margin"] < 1.0


def test_memory_margin_one_sided_tracks_reported(tracer):
    with obs.span("stage", "d2h", pod="p0", device="device0", bytes=100):
        pass
    (m,) = memory_calibration()
    assert m.modeled_bytes == 0 and m.measured_bytes == 100
    assert m.margin == 0.0
    obs.get_tracer().clear()
    obs.fleet_event("place", job="j", pod="p1", device="device0", bytes=64)
    (m2,) = memory_calibration()
    assert m2.measured_bytes == 0 and m2.margin == float("inf")
    assert m2.as_dict()["margin"] is None                # JSON-able


# --------------------------------------------------------------------------
# SLO accounting
# --------------------------------------------------------------------------

def test_slo_attainment_and_percentiles_per_priority():
    events = [
        _ev("submit", 0, job="a", priority=1),
        _ev("submit", 1, job="b", priority=1),
        _ev("submit", 2, job="c", priority=0),
        _ev("submit", 3, job="d", priority=1),
        # a: met (2.0 <= 5.0); b: late (9.0 > 5.0)
        _ev("complete", 4, job="a", priority=1, deadline_s=5.0,
            measured_s=2.0, queue_wait_s=0.5),
        _ev("complete", 5, job="b", priority=1, deadline_s=5.0,
            measured_s=9.0, queue_wait_s=4.0),
        # c: no deadline declared -> never counts against attainment
        _ev("complete", 6, job="c", priority=0, measured_s=1.0,
            queue_wait_s=0.1),
        # d: refused at admission with a deadline -> missed
        _ev("reject", 7, job="d", priority=1, deadline_s=1.0),
    ]
    rep = slo_report(events)
    tiers = {t["priority"]: t for t in rep["tiers"]}
    t1 = tiers[1]
    assert t1["submitted"] == 3 and t1["completed"] == 2
    assert t1["rejected"] == 1
    assert t1["deadline_jobs"] == 3 and t1["deadline_met"] == 1
    assert t1["attainment"] == pytest.approx(1 / 3)
    assert t1["latency_p95_s"] == pytest.approx(9.0)
    assert t1["queue_wait_p50_s"] in (0.5, 4.0)
    t0 = tiers[0]
    assert t0["deadline_jobs"] == 0 and t0["attainment"] == 1.0
    assert rep["overall_attainment"] == pytest.approx(1 / 3)
    assert rep["deadline_jobs"] == 3


def test_slo_priority_joined_via_submit_when_missing():
    events = [_ev("submit", 0, job="x", priority=2),
              _ev("complete", 1, job="x", deadline_s=10.0, measured_s=1.0)]
    rep = slo_report(events)
    (t,) = rep["tiers"]
    assert t["priority"] == 2 and t["attainment"] == 1.0


def test_slo_empty_stream_is_trivially_held():
    rep = slo_report([])
    assert rep["tiers"] == [] and rep["overall_attainment"] == 1.0


# --------------------------------------------------------------------------
# Prometheus exposition + live endpoint
# --------------------------------------------------------------------------

REQUIRED_FAMILIES = (
    "repro_calibration_samples_total", "repro_calibration_bias_seconds",
    "repro_calibration_abs_p95_seconds", "repro_calibration_drift",
    "repro_memory_modeled_bytes", "repro_memory_watermark_bytes",
    "repro_memory_margin_ratio", "repro_slo_attainment_ratio",
    "repro_slo_latency_p95_seconds", "repro_slo_queue_wait_p95_seconds",
    "repro_slo_completed_total",
)


def test_family_headers_present_even_when_empty(tracer):
    text = (calibration_prometheus(CalibrationLedger(), [])
            + slo_prometheus(slo_report([])))
    for fam in REQUIRED_FAMILIES:
        assert f"# TYPE {fam} " in text, fam


def test_http_round_trip_serves_live_families(tracer):
    sched = Scheduler(n_devices=1, memory=_mem(800), name="p0")
    sched.submit(_job(n_iter=2, priority=1, deadline_seconds=300.0))
    sched.run()
    with obs.MetricsServer(port=0) as srv:
        with urllib.request.urlopen(srv.url, timeout=10) as resp:
            assert resp.status == 200
            assert resp.headers["Content-Type"].startswith("text/plain")
            body = resp.read().decode("utf-8")
        # a second scrape re-reads the live tracer and still parses
        with urllib.request.urlopen(srv.url, timeout=10) as resp:
            assert resp.read().decode("utf-8") == body
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/nope", timeout=10)
    for fam in REQUIRED_FAMILIES:
        assert f"# TYPE {fam} " in body, fam
    # the run above produced real calibration series, not just headers
    assert 'repro_calibration_samples_total{' in body
    assert 'kind="step"' in body
    assert 'repro_slo_attainment_ratio{priority="1"} 1' in body


def test_validate_trace_gates_on_prom_families(tracer, tmp_path):
    with obs.span("s", "compute", job="j"):
        pass
    trace = str(tmp_path / "t.json")
    obs.write_chrome_trace(trace)
    good = tmp_path / "good.prom"
    good.write_text(obs.metrics_text())
    proc = subprocess.run(
        [sys.executable, "tools/validate_trace.py", trace,
         "--prom", str(good)], capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "families present" in proc.stdout
    # stripping one family header must fail the gate
    bad = tmp_path / "bad.prom"
    bad.write_text("\n".join(
        line for line in good.read_text().splitlines()
        if "repro_slo_attainment_ratio" not in line) + "\n")
    proc = subprocess.run(
        [sys.executable, "tools/validate_trace.py", trace,
         "--prom", str(bad)], capture_output=True, text=True)
    assert proc.returncode == 1
    assert "repro_slo_attainment_ratio" in proc.stdout
    # and so must a garbage series line
    ugly = tmp_path / "ugly.prom"
    ugly.write_text(good.read_text() + "repro_bogus{ not prometheus\n")
    proc = subprocess.run(
        [sys.executable, "tools/validate_trace.py", trace,
         "--prom", str(ugly)], capture_output=True, text=True)
    assert proc.returncode == 1


# --------------------------------------------------------------------------
# scheduler wiring: the ledger sees real serving traffic
# --------------------------------------------------------------------------

def test_scheduler_run_feeds_ledger_and_summary(tracer):
    sched = Scheduler(n_devices=1, memory=_mem(220), name="p0")
    for _ in range(2):
        sched.submit(_job(n_iter=2))
    sched.run()
    led = CalibrationLedger.from_events()
    kinds = led.samples_by_kind()
    assert kinds.get("admit", 0) >= 1
    assert kinds.get("step", 0) >= 2
    # every entry carries the enriched identity, not "-" placeholders
    for st in led.entries():
        if st.kind in ("admit", "step"):
            assert st.key.geometry == "16x16x16"
            assert st.key.algorithm == "cgls"
            assert st.key.pod == "p0"
    s = sched.summary()
    assert s["calibration"]["step"]["samples"] >= 2
    assert "bias_s" in s["calibration"]["step"]
    assert s["memory_modeled_peak_bytes"] > 0
    assert set(s["staging_seconds"]) == {"h2d", "prefetch", "d2h"}
    # the bandwidth EMA went public once staging bytes were observed
    if s["bandwidth_ema_bytes_per_s"] is not None:
        assert s["bandwidth_ema_bytes_per_s"] > 0


def test_merge_metrics_preserves_calibration_gauges():
    a = ServeMetrics(bandwidth_ema_bytes_per_s=100.0,
                     memory_modeled_peak_bytes=1000)
    a.record_calibration("step", 1.0, 1.5)
    b = ServeMetrics(bandwidth_ema_bytes_per_s=300.0,
                     memory_modeled_peak_bytes=4000)
    b.record_calibration("step", 1.0, 0.5)
    b.record_calibration("admit", 2.0, 2.0)
    c = ServeMetrics()          # a pod that saw no traffic
    m = merge_metrics([a, b, c])
    assert m.bandwidth_ema_bytes_per_s == pytest.approx(200.0)
    assert m.memory_modeled_peak_bytes == 4000
    assert sorted(m.calibration_errors_s["step"]) == [-0.5, 0.5]
    s = m.summary()
    assert s["calibration"]["step"]["samples"] == 2
    assert s["calibration"]["step"]["bias_s"] == pytest.approx(0.0)
    assert s["calibration"]["admit"]["abs_p95_s"] == pytest.approx(0.0)
    # one-sided observations never become samples
    c.record_calibration("step", None, 1.0)
    assert "step" not in c.calibration_errors_s


# --------------------------------------------------------------------------
# bench envelope schema + trajectory gate
# --------------------------------------------------------------------------

def _envelope(vals, bench="serve", direction="lower"):
    sys.path.insert(0, ".")
    from benchmarks import schema
    return schema.envelope(
        bench, config={"smoke": True},
        metrics=[schema.metric(n, v, "s", direction)
                 for n, v in vals.items()],
        smoke=True, configs={"x": {"completed": 1}})


def test_schema_envelope_validates_and_rejects():
    sys.path.insert(0, ".")
    from benchmarks import schema
    doc = _envelope({"wall_s": 1.0})
    assert schema.validate_envelope(doc) == []
    assert schema.metric_values(doc)["wall_s"]["value"] == 1.0
    with pytest.raises(ValueError):
        schema.metric("bad", float("nan"), "s")
    with pytest.raises(ValueError):
        schema.metric("bad", 1.0, "s", direction="sideways")
    with pytest.raises(ValueError):
        schema.envelope("b", config={}, metrics=[], **{"schema": 2})
    broken = dict(doc, metrics=[{"name": "x"}])
    assert schema.validate_envelope(broken)


def test_bench_track_seeds_then_gates(tmp_path):
    traj = tmp_path / "BENCH_T.json"

    def run_track(wall, extra=()):
        env = tmp_path / "env.json"
        env.write_text(json.dumps(_envelope({"wall_s": wall})))
        return subprocess.run(
            [sys.executable, "tools/bench_track.py", str(env),
             "--pr", "9", "--out", str(traj), *extra],
            capture_output=True, text=True)

    # first point: seeds, nothing to compare
    p = run_track(1.0)
    assert p.returncode == 0, p.stdout + p.stderr
    assert "seeded" in p.stdout and "no regression" in p.stdout
    # same value again: no regression, 2 points on file
    p = run_track(1.0)
    assert p.returncode == 0 and "no regression" in p.stdout
    doc = json.loads(traj.read_text())
    assert len(doc["points"]) == 2
    assert doc["points"][0]["metrics"]["serve.wall_s"]["value"] == 1.0
    # 20% worse: inside the fail band (40%) but past warn (15%)
    p = run_track(1.2)
    assert p.returncode == 0 and "WARN" in p.stdout
    # 3x worse: past the fail band -> gate trips, but point still lands
    p = run_track(3.6)
    assert p.returncode == 1 and "FAIL" in p.stdout
    assert len(json.loads(traj.read_text())["points"]) == 4
    # --baseline overrides the previous-point comparison
    base = tmp_path / "base.json"
    base.write_text(json.dumps(
        {"schema": 1, "points": [{"pr": 8, "metrics": {
            "serve.wall_s": {"value": 3.6, "units": "s",
                             "direction": "lower"}}}]}))
    p = run_track(3.6, extra=("--baseline", str(base)))
    assert p.returncode == 0 and "no regression" in p.stdout


def test_bench_track_direction_and_noise_floor(tmp_path):
    traj = tmp_path / "BENCH_T.json"
    env = tmp_path / "env.json"

    def run_track(doc):
        env.write_text(json.dumps(doc))
        return subprocess.run(
            [sys.executable, "tools/bench_track.py", str(env),
             "--pr", "9", "--out", str(traj)],
            capture_output=True, text=True)

    # higher-is-better metric dropping hard must fail ...
    run_track(_envelope({"rate": 100.0}, direction="higher"))
    p = run_track(_envelope({"rate": 10.0}, direction="higher"))
    assert p.returncode == 1 and "FAIL" in p.stdout
    # ... but a sub-noise-floor metric is never compared
    traj.unlink()
    run_track(_envelope({"tiny_s": 1e-4}))
    p = run_track(_envelope({"tiny_s": 9e-4}))      # 9x "worse", all noise
    assert p.returncode == 0 and "no regression" in p.stdout
