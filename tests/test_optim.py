"""Optimizer substrate: AdamW, clipping, schedules, int8 error-feedback
compression."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, compress_int8, cosine_schedule,
                         decompress_int8, ef_compress_update, global_norm,
                         linear_warmup, make_error_feedback_state)


def test_adamw_minimises_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, gn = clip_by_global_norm(tree, 1.0)
    assert float(gn) == pytest.approx(10.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # below the limit: untouched
    same, _ = clip_by_global_norm(tree, 100.0)
    np.testing.assert_allclose(same["a"], tree["a"])


def test_schedules():
    assert float(linear_warmup(0, 10, 1.0)) == pytest.approx(0.1)
    assert float(linear_warmup(9, 10, 1.0)) == pytest.approx(1.0)
    s0 = float(cosine_schedule(10, 10, 110, 1.0, floor=0.1))
    send = float(cosine_schedule(110, 10, 110, 1.0, floor=0.1))
    assert s0 == pytest.approx(1.0)
    assert send == pytest.approx(0.1, rel=1e-3)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 1000))
def test_int8_roundtrip_error_bound(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 10
    q, s = compress_int8(g)
    rec = decompress_int8(q, s)
    max_err = float(jnp.max(jnp.abs(rec - g)))
    assert max_err <= float(s) * 0.5 + 1e-6        # half-ulp of the scale
    assert q.dtype == jnp.int8


def test_error_feedback_preserves_signal():
    """With error feedback, the accumulated decompressed sum tracks the
    accumulated true gradient (bias does not accumulate)."""
    rng = jax.random.PRNGKey(0)
    grads = [{"w": jax.random.normal(jax.random.fold_in(rng, i), (32,))}
             for i in range(50)]
    ef = make_error_feedback_state(grads[0])
    acc_true = jnp.zeros(32)
    acc_rec = jnp.zeros(32)
    for g in grads:
        qtree, ef = ef_compress_update(g, ef)
        q, s = qtree["w"]
        acc_rec = acc_rec + decompress_int8(q, s)
        acc_true = acc_true + g["w"]
    rel = float(jnp.linalg.norm(acc_rec - acc_true)
                / jnp.linalg.norm(acc_true))
    assert rel < 0.05, rel


def test_zero1_spec(host_mesh):
    from jax.sharding import PartitionSpec as P
    from repro.optim.adamw import zero1_spec
    # dim0 free and divisible by data size (4)
    assert zero1_spec(P(None, "model"), (8, 16), ("data",), host_mesh) == \
        P("data", "model")
    # dim0 sharded -> next free divisible dim
    assert zero1_spec(P("model", None), (16, 8), ("data",), host_mesh) == \
        P("model", "data")
    # nothing divisible -> unchanged
    assert zero1_spec(P(None,), (7,), ("data",), host_mesh) == P(None)
