"""Projector correctness: analytic oracle, interp-vs-joseph agreement,
adjoint property, geometry edge cases."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import phantoms
from repro.core.geometry import ConeGeometry, circular_angles, \
    dominant_axis_mask
from repro.core.projector import (backproject_matched, backproject_voxel,
                                  forward_project, forward_project_interp)


GEO32 = ConeGeometry.nice(32)
ANGLES8 = circular_angles(8)


def test_joseph_matches_analytic_sphere():
    vol = jnp.asarray(phantoms.sphere(GEO32))
    got = forward_project(vol, GEO32, ANGLES8)
    want = phantoms.sphere_projection_analytic(GEO32, ANGLES8)
    rel = np.linalg.norm(np.asarray(got) - want) / np.linalg.norm(want)
    assert rel < 0.08, rel


def test_joseph_matches_interp():
    vol = jnp.asarray(phantoms.sphere(GEO32))
    pj = forward_project(vol, GEO32, ANGLES8)
    pi = forward_project_interp(vol, GEO32, jnp.asarray(ANGLES8))
    rel = float(jnp.linalg.norm(pj - pi) / jnp.linalg.norm(pi))
    assert rel < 0.03, rel


def test_shepp_logan_analytic():
    vol = jnp.asarray(phantoms.shepp_logan(GEO32))
    got = forward_project(vol, GEO32, ANGLES8)
    want = phantoms.shepp_logan_projection_analytic(GEO32, ANGLES8)
    rel = np.linalg.norm(np.asarray(got) - want) / np.linalg.norm(want)
    assert rel < 0.25, rel


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_adjoint_property(seed):
    """<Ax, y> == <x, A^T y> for the matched pair (hypothesis seeds)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, GEO32.n_voxel)
    y = jax.random.normal(k2, (len(ANGLES8),) + GEO32.n_detector)
    lhs = float(jnp.vdot(forward_project(x, GEO32, ANGLES8), y))
    rhs = float(jnp.vdot(x, backproject_matched(y, GEO32,
                                                jnp.asarray(ANGLES8))))
    assert abs(lhs - rhs) / (abs(lhs) + 1e-9) < 1e-4


def test_fp_linearity():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(k1, GEO32.n_voxel)
    b = jax.random.normal(k2, GEO32.n_voxel)
    pab = forward_project(a + 2.0 * b, GEO32, ANGLES8)
    pa = forward_project(a, GEO32, ANGLES8)
    pb = forward_project(b, GEO32, ANGLES8)
    np.testing.assert_allclose(pab, pa + 2.0 * pb, rtol=1e-3, atol=1e-3)


def test_bp_additivity_over_angles():
    """BP is additive over angle subsets (the streaming invariant)."""
    proj = jax.random.normal(jax.random.PRNGKey(1),
                             (8,) + GEO32.n_detector)
    angles = jnp.asarray(ANGLES8)
    full = backproject_voxel(proj, GEO32, angles)
    parts = (backproject_voxel(proj[:4], GEO32, angles[:4])
             + backproject_voxel(proj[4:], GEO32, angles[4:]))
    np.testing.assert_allclose(full, parts, rtol=1e-4, atol=1e-4)


def test_offset_detector():
    geo = ConeGeometry.nice(32)
    import dataclasses
    geo = dataclasses.replace(geo, off_detector=(6.0, -8.0))
    vol = jnp.asarray(phantoms.sphere(geo))
    got = forward_project(vol, geo, ANGLES8)
    want = phantoms.sphere_projection_analytic(geo, ANGLES8)
    rel = np.linalg.norm(np.asarray(got) - want) / np.linalg.norm(want)
    assert rel < 0.1, rel


def test_fan_angle_guard():
    with pytest.raises(ValueError):
        ConeGeometry(DSD=500.0, DSO=400.0, s_detector=(2000.0, 2000.0))


def test_dominant_axis_mask():
    m = dominant_axis_mask(np.asarray([0.0, np.pi / 2, np.pi / 4 + 0.01]))
    assert m.tolist() == [True, False, False]
