"""Paper SS2.3: halo-split TV regularisers vs monolithic; approximate-norm
convergence claim; halo-depth bookkeeping."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.regularization import (dist_minimize_tv, dist_rof_denoise,
                                       halo_overhead, minimize_tv,
                                       rof_denoise, tv_gradient, tv_value)


def _vol(seed=0, shape=(32, 12, 12)):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def test_tv_gradient_is_grad_of_value():
    v = _vol(1, (8, 8, 8))
    g = tv_gradient(v, 1e-6)
    gn = jax.grad(lambda x: tv_value(x, 1e-6))(v)
    np.testing.assert_allclose(g, gn, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_inner", [1, 2, 4])
def test_dist_tv_exact_norm_matches_mono(host_mesh, n_inner):
    v = _vol(2)
    fn = dist_minimize_tv(host_mesh, hyper=0.1, n_iters=8, n_inner=n_inner,
                          approx_norm=False)
    with host_mesh:
        got = fn(v)
    want = minimize_tv(v, hyper=0.1, n_iters=8)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_dist_tv_approx_norm_converges(host_mesh):
    """Paper SS2.3: the no-sync norm approximation has negligible effect on
    the result (claim tested: relative deviation < 2%)."""
    v = _vol(3)
    with host_mesh:
        approx = dist_minimize_tv(host_mesh, 0.1, 12, 4, approx_norm=True)(v)
        exact = dist_minimize_tv(host_mesh, 0.1, 12, 4, approx_norm=False)(v)
    rel = float(jnp.linalg.norm(approx - exact)
                / jnp.linalg.norm(exact))
    assert rel < 0.02, rel
    # and both reduce TV versus the input (materialise to host first: on
    # some jax versions elementwise graphs evaluated directly on the
    # mesh-sharded output produce wrong values)
    approx_host = jnp.asarray(np.asarray(approx))
    assert float(tv_value(approx_host)) < float(tv_value(v))


@pytest.mark.parametrize("n_inner", [2, 4])
def test_dist_rof_matches_mono(host_mesh, n_inner):
    v = _vol(4)
    fn = dist_rof_denoise(host_mesh, lam=10.0, n_iters=8, n_inner=n_inner)
    with host_mesh:
        got = fn(v)
    want = rof_denoise(v, lam=10.0, n_iters=8)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-5)


def test_rof_denoises():
    clean = jnp.zeros((16, 16, 16)).at[4:12, 4:12, 4:12].set(1.0)
    noisy = clean + 0.2 * jax.random.normal(jax.random.PRNGKey(5),
                                            clean.shape)
    den = rof_denoise(noisy, lam=20.0, n_iters=30)
    assert float(jnp.linalg.norm(den - clean)) < \
        float(jnp.linalg.norm(noisy - clean))


def test_halo_overhead():
    assert halo_overhead(100, 10) == pytest.approx(0.2)
    assert halo_overhead(10, 60) == pytest.approx(12.0)
