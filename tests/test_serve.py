"""repro.serve: queue ordering, planner-driven placement, step-wise
equivalence, preemption (per-device), weighted fair share, deadline
admission, the threaded AsyncDriver, durable kill/rebuild resume, and
end-to-end concurrent mixed-size serving."""

import dataclasses
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import phantoms
from repro.core.algorithms import (asd_pocs, cgls, fista_tv, ossart,
                                   get_algorithm)
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.splitting import MemoryModel
from repro.checkpoint import PreemptionGuard
from repro.serve import (AsyncDriver, DevicePool, JobStatus, JobExecutor,
                         PriorityJobQueue, ReconJob, Scheduler,
                         estimate_job_footprint, percentile)
from repro.serve.job import JobRecord

GEO = ConeGeometry.nice(16)
ANGLES = circular_angles(12)
PROJ = phantoms.sphere_projection_analytic(GEO, ANGLES)

BIG_GEO = ConeGeometry.nice(32)
BIG_ANGLES = circular_angles(16)

KIB = 1024


def _mem(kib, frac=1.0):
    return MemoryModel(device_bytes=kib * KIB, usable_fraction=frac)


def _job(alg="cgls", prio=0, n_iter=2, **kw):
    return ReconJob(alg, GEO, ANGLES, PROJ, n_iter=n_iter, priority=prio,
                    **kw)


def _rec(job, seq):
    return JobRecord(job=job, seq=seq)


# --------------------------------------------------------------------------
# queue
# --------------------------------------------------------------------------

def test_queue_priority_then_fifo():
    q = PriorityJobQueue()
    lo1, hi, lo2 = _job(prio=0), _job(prio=5), _job(prio=0)
    q.push(_rec(lo1, 0)); q.push(_rec(hi, 1)); q.push(_rec(lo2, 2))
    assert q.peek_priority() == 5
    order = [q.pop().job.job_id for _ in range(3)]
    assert order == [hi.job_id, lo1.job_id, lo2.job_id]
    assert q.pop() is None


def test_queue_requeue_preserves_position():
    """A preempted job re-enters ahead of later arrivals of equal prio."""
    q = PriorityJobQueue()
    first, second = _job(prio=1), _job(prio=1)
    q.push(_rec(first, 0)); q.push(_rec(second, 1))
    got = q.pop()
    assert got.job.job_id == first.job_id
    q.push(got)                     # preemption path: same record, same seq
    assert q.pop().job.job_id == first.job_id


def test_queue_cancel():
    q = PriorityJobQueue()
    a, b = _job(), _job()
    q.push(_rec(a, 0)); q.push(_rec(b, 1))
    assert q.cancel(a.job_id)
    assert not q.cancel("nope")
    assert q.pop().job.job_id == b.job_id
    assert len(q) == 0


def test_queue_concurrent_submit_cancel_pop():
    """Hammer the queue from several threads: every job must come out
    exactly once (popped XOR successfully cancelled), with no errors."""
    q = PriorityJobQueue()
    n_per_thread, n_submitters = 150, 2
    submitted = [[] for _ in range(n_submitters)]
    popped, cancelled = [], []
    errors = []
    done = threading.Event()

    def submitter(t):
        try:
            for i in range(n_per_thread):
                job = _job(prio=i % 5)
                q.push(_rec(job, t * n_per_thread + i))
                submitted[t].append(job.job_id)
        except Exception as e:           # pragma: no cover
            errors.append(e)

    def canceller():
        try:
            while not done.is_set():
                for t in range(n_submitters):
                    for jid in submitted[t][-3:]:
                        if q.cancel(jid):
                            cancelled.append(jid)
                time.sleep(0)
        except Exception as e:           # pragma: no cover
            errors.append(e)

    def popper(out):
        try:
            while True:
                rec = q.pop()
                if rec is not None:
                    out.append(rec.job.job_id)
                elif done.is_set():
                    return
        except Exception as e:           # pragma: no cover
            errors.append(e)

    outs = [[], []]
    threads = ([threading.Thread(target=submitter, args=(t,))
                for t in range(n_submitters)]
               + [threading.Thread(target=canceller)]
               + [threading.Thread(target=popper, args=(o,)) for o in outs])
    for t in threads:
        t.start()
    for t in threads[:n_submitters]:
        t.join()
    time.sleep(0.05)                     # let poppers/canceller drain
    done.set()
    for t in threads[n_submitters:]:
        t.join()

    assert not errors
    popped = outs[0] + outs[1]
    all_ids = {jid for ids in submitted for jid in ids}
    assert len(popped) == len(set(popped))          # no duplicates
    assert set(popped).isdisjoint(cancelled)        # popped XOR cancelled
    assert set(popped) | set(cancelled) == all_ids  # nothing lost
    assert len(q) == 0


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------

def test_percentile_edge_cases():
    assert percentile([], 50) == 0.0
    for p in (0, 50, 100):               # single sample: always that sample
        assert percentile([3.5], p) == 3.5
    xs = [4.0, 1.0, 3.0, 2.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 4.0
    assert percentile(xs, 50) == 3.0     # nearest-rank on the sorted list


# --------------------------------------------------------------------------
# footprint estimation + placement
# --------------------------------------------------------------------------

def test_footprint_small_job_resident():
    fp = estimate_job_footprint(_job("cgls"), _mem(1024))
    assert not fp.streams
    # 3 volume copies + 3 projection-set copies for CGLS at 16^3 / 12 angles
    assert fp.bytes_on_device == 3 * 16**3 * 4 + 3 * 12 * 16 * 16 * 4


def test_footprint_oversized_job_streams():
    job = ReconJob("ossart", BIG_GEO, BIG_ANGLES, lambda: None)
    fp = estimate_job_footprint(job, _mem(220))
    assert fp.streams
    assert fp.bytes_on_device <= _mem(220).usable


def test_footprint_respects_forced_mode_and_hint():
    assert estimate_job_footprint(_job(mode="stream"), _mem(1024)).streams
    fp = estimate_job_footprint(_job(memory_hint_bytes=12345), _mem(1024))
    assert fp.bytes_on_device == 12345


def test_pool_placement_respects_budget():
    pool = DevicePool(n_devices=2, memory=_mem(100))
    cap = pool.memory.usable
    s1 = pool.best_fit(60 * KIB)
    pool.commit(s1, "a", 60 * KIB)
    s2 = pool.best_fit(60 * KIB)          # does not fit next to "a"
    assert s2 is not s1
    pool.commit(s2, "b", 60 * KIB)
    assert pool.best_fit(60 * KIB) is None   # pool full for this size
    assert pool.best_fit(cap - 60 * KIB) is not None  # small one still fits
    pool.release(s1, "a", 60 * KIB)
    assert pool.best_fit(60 * KIB) is s1
    assert s1.free_bytes == cap


def test_pool_spread_vs_pack():
    spread = DevicePool(n_devices=2, memory=_mem(100))
    a = spread.best_fit(10 * KIB); spread.commit(a, "a", 10 * KIB)
    assert spread.best_fit(10 * KIB) is not a      # least-loaded first
    pack = DevicePool(n_devices=2, memory=_mem(100), policy="pack")
    b = pack.best_fit(10 * KIB); pack.commit(b, "b", 10 * KIB)
    assert pack.best_fit(10 * KIB) is b            # tightest fit first


def test_scheduler_isolates_bad_tenants():
    sched = Scheduler(n_devices=1)
    with pytest.raises(ValueError, match="unknown algorithm"):
        sched.submit(_job("not-an-algorithm"))
    # a job whose init blows up (bad data ref) fails alone; the scheduler
    # keeps serving the healthy tenant
    bad = sched.submit(ReconJob("cgls", GEO, ANGLES,
                                lambda: 1 / 0, n_iter=2))
    good = sched.submit(_job("cgls", n_iter=2))
    sched.run()
    assert sched.records[bad].status is JobStatus.FAILED
    assert "init failed" in sched.records[bad].error
    assert sched.records[good].status is JobStatus.COMPLETED
    np.testing.assert_array_equal(sched.result(good), _mono("cgls", 2))


def test_scheduler_fails_never_fitting_job():
    sched = Scheduler(n_devices=1, memory=_mem(100))
    jid = sched.submit(_job("cgls", memory_hint_bytes=10 * 1024 * KIB))
    sched.run(max_quanta=2)
    rec = sched.records[jid]
    assert rec.status is JobStatus.FAILED
    assert "exceeds" in rec.error
    with pytest.raises(RuntimeError):
        sched.result(jid)


# --------------------------------------------------------------------------
# step-wise iterators == monolithic algorithms (bit-for-bit)
# --------------------------------------------------------------------------

_MONO_MEMO = {}


def _mono(alg, n_iter):
    if (alg, n_iter) in _MONO_MEMO:
        return _MONO_MEMO[(alg, n_iter)]
    _MONO_MEMO[(alg, n_iter)] = _mono_run(alg, n_iter)
    return _MONO_MEMO[(alg, n_iter)]


def _mono_run(alg, n_iter):
    if alg == "cgls":
        return np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=n_iter))
    if alg == "ossart":
        return np.asarray(ossart(PROJ, GEO, ANGLES, n_iter=n_iter,
                                 subset_size=4))
    if alg == "fista":
        return np.asarray(fista_tv(PROJ, GEO, ANGLES, n_iter=n_iter,
                                   tv_iters=3, L=100.0))
    if alg == "asd_pocs":
        return np.asarray(asd_pocs(PROJ, GEO, ANGLES, n_iter=n_iter,
                                   subset_size=4, tv_iters=3))
    raise AssertionError(alg)


_PARAMS = {"ossart": {"subset_size": 4},
           "fista": {"tv_iters": 3, "L": 100.0},   # fixed L skips power it.
           "asd_pocs": {"subset_size": 4, "tv_iters": 3}, "cgls": {}}


@pytest.mark.parametrize("alg", ["cgls", "ossart", "fista", "asd_pocs"])
def test_stepwise_matches_monolithic_bitwise(alg):
    n_iter = 2
    a = get_algorithm(alg)
    st = a.init(PROJ, GEO, ANGLES, **_PARAMS[alg])
    for _ in range(n_iter):
        st = a.step(st)
    got = np.asarray(a.finalize(st))
    np.testing.assert_array_equal(got, _mono(alg, n_iter))


# --------------------------------------------------------------------------
# preemption
# --------------------------------------------------------------------------

def test_preemption_prioritizes_urgent_job_and_preserves_result():
    # budget fits exactly one resident small job (84 KiB < 100 KiB < 168)
    sched = Scheduler(n_devices=1, memory=_mem(100))
    lo = sched.submit(_job("ossart", prio=0, n_iter=4,
                           params={"subset_size": 4}))
    sched.run(max_quanta=2)          # low-prio makes some progress
    assert sched.records[lo].iterations_done >= 1
    hi = sched.submit(_job("cgls", prio=9, n_iter=2))
    sched.run()
    rec_lo, rec_hi = sched.records[lo], sched.records[hi]
    assert rec_lo.preemptions >= 1
    assert rec_hi.end_time <= rec_lo.end_time
    assert sched.metrics.preemptions >= 1
    # both results bit-identical to uninterrupted monolithic runs
    np.testing.assert_array_equal(
        sched.result(lo), np.asarray(ossart(PROJ, GEO, ANGLES, n_iter=4,
                                            subset_size=4)))
    np.testing.assert_array_equal(sched.result(hi), _mono("cgls", 2))


def test_guard_drain_and_resume_with_lazy_data_ref():
    calls = []

    def ref():                       # lazy data ref, resolved at admission
        calls.append(1)
        return PROJ

    guard = PreemptionGuard(install_handler=False)
    sched = Scheduler(n_devices=1, guard=guard)
    jid = sched.submit(ReconJob("cgls", GEO, ANGLES, ref, n_iter=3))
    assert not calls                 # nothing resolved at submit time
    sched.run(max_quanta=1)
    assert calls == [1]
    guard.trigger()                  # host SIGTERM equivalent
    sched.run()
    rec = sched.records[jid]
    assert rec.status is JobStatus.PREEMPTED
    assert rec.checkpoint is not None
    sched.guard = None               # "restarted" host
    sched.run()
    assert rec.status is JobStatus.COMPLETED
    assert calls == [1, 1]           # re-resolved on re-admission
    np.testing.assert_array_equal(sched.result(jid), _mono("cgls", 3))


# --------------------------------------------------------------------------
# end-to-end: concurrent mixed-size serving
# --------------------------------------------------------------------------

def test_concurrent_mixed_size_jobs_match_solo_runs():
    """>= 3 jobs of mixed sizes share a small-memory pool concurrently;
    every result is numerically identical to a solo monolithic run."""
    big_proj = phantoms.sphere_projection_analytic(BIG_GEO, BIG_ANGLES)
    sched = Scheduler(n_devices=3, memory=_mem(220))
    jids = [
        sched.submit(_job("cgls", n_iter=2)),
        sched.submit(_job("ossart", n_iter=2, params={"subset_size": 4})),
        sched.submit(_job("cgls", n_iter=3)),
        sched.submit(ReconJob("ossart", BIG_GEO, BIG_ANGLES, big_proj,
                              n_iter=1, params={"subset_size": 16})),
    ]
    max_running = 0
    while not sched.idle:
        sched.step_quantum()
        max_running = max(max_running, len(sched.running))
    assert max_running >= 3          # genuinely concurrent
    recs = [sched.records[j] for j in jids]
    assert all(r.status is JobStatus.COMPLETED for r in recs)
    assert recs[3].streamed          # the big one went out-of-core
    assert len({r.device for r in recs[:3]}) > 1   # packed across devices

    np.testing.assert_array_equal(sched.result(jids[0]), _mono("cgls", 2))
    np.testing.assert_array_equal(sched.result(jids[1]),
                                  _mono("ossart", 2))
    np.testing.assert_array_equal(sched.result(jids[2]), _mono("cgls", 3))
    solo_big = np.asarray(ossart(big_proj, BIG_GEO, BIG_ANGLES, n_iter=1,
                                 subset_size=16))
    got_big = sched.result(jids[3])
    np.testing.assert_allclose(got_big, solo_big, rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# step accounting under async dispatch
# --------------------------------------------------------------------------

def test_step_time_includes_compute_not_just_dispatch(monkeypatch):
    """JAX dispatch is async: without blocking on the state's arrays the
    timed 'step' is just the enqueue.  A sleep-instrumented kernel makes
    the difference observable: the measured step must take at least the
    kernel's sleep."""
    from repro.core.algorithms import stepwise

    delay = 0.1

    @dataclasses.dataclass
    class SleepyState:
        x: jnp.ndarray
        it: int = 0

    def sleepy_init(proj, geo, angles, op=None, **_params):
        return SleepyState(x=jnp.zeros(geo.n_voxel, jnp.float32))

    def sleepy_step(st):
        def slow_kernel(x):
            time.sleep(delay)
            return x

        out = jax.ShapeDtypeStruct(st.x.shape, st.x.dtype)
        st.x = jax.jit(
            lambda x: jax.pure_callback(slow_kernel, out, x))(st.x)
        st.it += 1
        return st

    alg = stepwise.StepwiseAlgorithm(
        "sleepy", sleepy_init, sleepy_step, lambda st: st.x,
        ckpt_fields=("x", "it"))
    monkeypatch.setitem(stepwise.REGISTRY, "sleepy", alg)

    ex = JobExecutor(ReconJob("sleepy", GEO, ANGLES, PROJ, n_iter=1),
                     mode="plain", memory=_mem(1024))
    ex.start()
    t0 = time.monotonic()
    ex.step()
    assert time.monotonic() - t0 >= delay


def test_place_releases_executor_when_start_raises(monkeypatch):
    released = []
    orig_release = JobExecutor.release

    def tracking_release(self):
        released.append(self.job.job_id)
        orig_release(self)

    monkeypatch.setattr(JobExecutor, "release", tracking_release)
    sched = Scheduler(n_devices=1)
    bad = sched.submit(ReconJob("cgls", GEO, ANGLES,
                                lambda: 1 / 0, n_iter=1))
    sched.run()
    assert sched.records[bad].status is JobStatus.FAILED
    assert bad in released


# --------------------------------------------------------------------------
# weighted fair share
# --------------------------------------------------------------------------

def test_weighted_fair_share_cooperative_quantum():
    """Per quantum, a job receives 1 + priority steps."""
    sched = Scheduler(n_devices=1, memory=_mem(1024))
    lo = sched.submit(_job("cgls", prio=0, n_iter=8))
    hi = sched.submit(_job("cgls", prio=3, n_iter=8))
    sched.step_quantum()
    assert sched.records[hi].iterations_done == 4
    assert sched.records[lo].iterations_done == 1


def test_weighted_fair_share_stride_claims():
    """The driver-facing claim API awards device steps proportional to
    priority weights (stride scheduling over virtual time)."""
    sched = Scheduler(n_devices=1, memory=_mem(1024))
    lo = sched.submit(_job("cgls", prio=0, n_iter=100))
    hi = sched.submit(_job("cgls", prio=3, n_iter=100))
    sched.admit()
    slot = sched.pool.slots[0]
    counts = {lo: 0, hi: 0}
    for _ in range(10):
        run = sched.claim_step(slot)
        counts[run.record.job.job_id] += 1
        sched.finish_step(run, 0.0)     # bookkeeping only, no compute
    assert counts[hi] == 8              # weight 4 of 5
    assert counts[lo] == 2              # weight 1 of 5


# --------------------------------------------------------------------------
# per-device preemption
# --------------------------------------------------------------------------

def test_preemption_is_per_device():
    """Freed bytes on different slots don't combine: the scheduler must
    evict only on the one device where eviction makes the arrival fit.
    Layout (100 KiB devices): dev0 = H(50K, prio 9) + V0(30K, prio 0);
    dev1 = V1(80K, prio 0).  A 60K prio-5 arrival fits dev1 after
    evicting V1, but never fits dev0 (H is higher priority) — so V0 must
    keep running untouched."""
    sched = Scheduler(n_devices=2, memory=_mem(100))
    h = sched.submit(_job("cgls", prio=9, n_iter=30,
                          memory_hint_bytes=50 * KIB))
    v1 = sched.submit(_job("cgls", prio=0, n_iter=6,
                           memory_hint_bytes=80 * KIB))
    v0 = sched.submit(_job("cgls", prio=0, n_iter=6,
                           memory_hint_bytes=30 * KIB))
    sched.run(max_quanta=1)
    assert sched.records[h].device == 0
    assert sched.records[v1].device == 1
    assert sched.records[v0].device == 0
    p = sched.submit(_job("cgls", prio=5, n_iter=1,
                          memory_hint_bytes=60 * KIB))
    sched.step_quantum()
    assert sched.records[v1].preemptions == 1      # dev1's victim parked
    assert sched.records[v0].preemptions == 0      # dev0's job untouched
    assert sched.records[v0].status is JobStatus.RUNNING
    assert sched.records[p].device == 1
    sched.run()
    assert all(sched.records[j].status is JobStatus.COMPLETED
               for j in (h, v1, v0, p))
    np.testing.assert_array_equal(sched.result(v1), _mono("cgls", 6))


# --------------------------------------------------------------------------
# deadline-aware admission
# --------------------------------------------------------------------------

def test_deadline_admission_rejects_unmeetable_jobs():
    sched = Scheduler(n_devices=1, memory=_mem(1024))
    warm = sched.submit(_job("cgls", n_iter=2))    # seeds the step-cost EMA
    sched.run()
    late = sched.submit(_job("cgls", n_iter=50, deadline_seconds=1e-6))
    fine = sched.submit(_job("cgls", n_iter=2, deadline_seconds=3600.0))
    sched.run()
    assert sched.records[warm].status is JobStatus.COMPLETED
    assert sched.records[late].status is JobStatus.FAILED
    assert "deadline" in sched.records[late].error
    assert sched.records[fine].status is JobStatus.COMPLETED
    assert sched.metrics.deadline_rejected == 1


def test_deadline_admission_optimistic_without_observations():
    """With no observed step costs the model abstains and admits."""
    sched = Scheduler(n_devices=1, memory=_mem(1024))
    jid = sched.submit(_job("cgls", n_iter=2, deadline_seconds=1e-6))
    sched.run()
    assert sched.records[jid].status is JobStatus.COMPLETED


# --------------------------------------------------------------------------
# threaded AsyncDriver
# --------------------------------------------------------------------------

def test_async_driver_matches_solo_runs_across_devices():
    sched = Scheduler(n_devices=2, memory=_mem(220))
    jids = [
        sched.submit(_job("cgls", n_iter=2)),
        sched.submit(_job("ossart", n_iter=2, params={"subset_size": 4})),
        sched.submit(_job("cgls", n_iter=3)),
        sched.submit(_job("fista", n_iter=2,
                          params={"tv_iters": 3, "L": 100.0})),
    ]
    metrics = AsyncDriver(sched).run(timeout=300)
    recs = [sched.records[j] for j in jids]
    assert all(r.status is JobStatus.COMPLETED for r in recs)
    assert metrics.completed == 4
    busy = sched.pool.busy_clocks()
    assert all(b > 0 for b in busy)      # both worker threads did real work
    assert len({r.device for r in recs}) == 2
    np.testing.assert_array_equal(sched.result(jids[0]), _mono("cgls", 2))
    np.testing.assert_array_equal(sched.result(jids[1]), _mono("ossart", 2))
    np.testing.assert_array_equal(sched.result(jids[2]), _mono("cgls", 3))
    np.testing.assert_array_equal(sched.result(jids[3]), _mono("fista", 2))


def test_async_driver_kill_rebuild_restores_bit_identical(tmp_path):
    """Kill the threaded driver mid-run, drain durably, rebuild a fresh
    scheduler from the on-disk snapshot (manifest + COMMIT per job), and
    finish: final volumes are bit-identical to uninterrupted runs."""
    ckpt_dir = str(tmp_path / "serve-ckpt")
    s1 = Scheduler(n_devices=1, memory=_mem(100))   # one resident at a time
    a = s1.submit(_job("ossart", n_iter=12, params={"subset_size": 4}))
    b = s1.submit(_job("cgls", n_iter=10))
    driver = AsyncDriver(s1)
    driver.start()
    deadline = time.monotonic() + 60
    while time.monotonic() < deadline:
        if s1.records[a].iterations_done >= 1:
            break
        time.sleep(0.001)
    driver.stop()                                    # "kill": step boundary
    assert s1.records[a].iterations_done >= 1
    parked = s1.drain(ckpt_dir)
    assert parked >= 1
    live = [j for j in (a, b) if not s1.records[j].done]
    assert live                                      # something to restore
    for jid in live:                                 # committed snapshots
        job_dir = os.path.join(ckpt_dir, "jobs", jid)
        steps = [d for d in os.listdir(job_dir) if d.startswith("step_")]
        assert steps
        assert all(os.path.exists(os.path.join(job_dir, d, "COMMIT"))
                   for d in steps)

    s2 = Scheduler(n_devices=1, memory=_mem(100),    # "process restart"
                   snapshot_dir=ckpt_dir)
    assert s2.restore(ckpt_dir) == len(live)
    for jid in live:
        assert s2.records[jid].iterations_done == \
            s1.records[jid].iterations_done
    AsyncDriver(s2).run(timeout=300)

    want = {a: _mono("ossart", 12), b: _mono("cgls", 10)}
    for jid in (a, b):
        src = s2 if jid in s2.records else s1
        np.testing.assert_array_equal(src.result(jid), want[jid])

    # completion flips the on-disk specs terminal: a third restart finds
    # no resurrectable work
    assert Scheduler(n_devices=1, memory=_mem(100)).restore(ckpt_dir) == 0


def test_async_driver_guard_preemption_drains_durably(tmp_path):
    """A SIGTERM-equivalent mid-run under the driver parks + persists the
    running job; a fresh scheduler restores and finishes bit-identically."""
    ckpt_dir = str(tmp_path / "serve-ckpt")
    guard = PreemptionGuard(install_handler=False)
    sched = Scheduler(n_devices=1, guard=guard, snapshot_dir=ckpt_dir)
    jid = sched.submit(_job("cgls", n_iter=30))

    def trigger_after_progress():
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            if sched.records[jid].iterations_done >= 1:
                break
            time.sleep(0.001)
        guard.trigger()

    killer = threading.Thread(target=trigger_after_progress)
    killer.start()
    AsyncDriver(sched).run(timeout=300)
    killer.join()
    rec = sched.records[jid]
    assert rec.status is JobStatus.PREEMPTED
    assert 1 <= rec.iterations_done < 30
    assert rec.checkpoint is not None

    s2 = Scheduler(n_devices=1)
    assert s2.restore(ckpt_dir) == 1
    s2.run()
    np.testing.assert_array_equal(s2.result(jid), _mono("cgls", 30))


def test_cancel_stales_out_persisted_snapshot(tmp_path):
    """Cancelling a queued job after it was snapshotted must prevent a
    later restore from resurrecting (and executing) it."""
    ckpt_dir = str(tmp_path / "serve-ckpt")
    sched = Scheduler(n_devices=1, memory=_mem(100),
                      snapshot_dir=ckpt_dir)
    busy = sched.submit(_job("cgls", n_iter=4))      # holds the only slot
    victim = sched.submit(_job("cgls", n_iter=2))
    sched.step_quantum()
    assert sched.records[victim].status is JobStatus.PENDING
    # parked jobs only: the property under test is the cancel stale-out
    assert sched.snapshot(ckpt_dir, include_running=False) == 1
    assert sched.cancel(victim)
    sched.run()
    assert sched.records[busy].status is JobStatus.COMPLETED
    assert Scheduler(n_devices=1).restore(ckpt_dir) == 0


def test_async_driver_surfaces_internal_errors(monkeypatch, tmp_path):
    """An internal failure (here: the periodic snapshot machinery) must
    stop the driver and raise, not silently kill a daemon thread and
    hang run() forever."""
    sched = Scheduler(n_devices=1, memory=_mem(1024))
    sched.submit(_job("cgls", n_iter=50))

    def broken_snapshot(ckpt_dir, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(sched, "snapshot", broken_snapshot)
    driver = AsyncDriver(sched, snapshot_dir=str(tmp_path / "snap"),
                         snapshot_every_seconds=1e-4)
    with pytest.raises(RuntimeError, match="internal error"):
        driver.run(timeout=120)
    assert isinstance(driver.error, OSError)


def test_restore_requires_data_ref_for_lazy_jobs(tmp_path):
    ckpt_dir = str(tmp_path / "serve-ckpt")
    calls = []

    def ref():
        calls.append(1)
        return PROJ

    s1 = Scheduler(n_devices=1)
    jid = s1.submit(ReconJob("cgls", GEO, ANGLES, ref, n_iter=3))
    s1.run(max_quanta=1)
    s1.drain(ckpt_dir)
    s2 = Scheduler(n_devices=1)
    with pytest.raises(ValueError, match="lazy"):
        s2.restore(ckpt_dir)
    s3 = Scheduler(n_devices=1)
    assert s3.restore(ckpt_dir, data_refs={jid: ref}) == 1
    s3.run()
    np.testing.assert_array_equal(s3.result(jid), _mono("cgls", 3))


