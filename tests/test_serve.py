"""repro.serve: queue ordering, planner-driven placement, step-wise
equivalence, preemption, and end-to-end concurrent mixed-size serving."""

import numpy as np
import pytest

from repro.core import phantoms
from repro.core.algorithms import (asd_pocs, cgls, fista_tv, ossart,
                                   get_algorithm)
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.splitting import MemoryModel
from repro.checkpoint import PreemptionGuard
from repro.serve import (DevicePool, JobStatus, PriorityJobQueue, ReconJob,
                         Scheduler, estimate_job_footprint)
from repro.serve.job import JobRecord

GEO = ConeGeometry.nice(16)
ANGLES = circular_angles(12)
PROJ = phantoms.sphere_projection_analytic(GEO, ANGLES)

BIG_GEO = ConeGeometry.nice(32)
BIG_ANGLES = circular_angles(16)

KIB = 1024


def _mem(kib, frac=1.0):
    return MemoryModel(device_bytes=kib * KIB, usable_fraction=frac)


def _job(alg="cgls", prio=0, n_iter=2, **kw):
    return ReconJob(alg, GEO, ANGLES, PROJ, n_iter=n_iter, priority=prio,
                    **kw)


def _rec(job, seq):
    return JobRecord(job=job, seq=seq)


# --------------------------------------------------------------------------
# queue
# --------------------------------------------------------------------------

def test_queue_priority_then_fifo():
    q = PriorityJobQueue()
    lo1, hi, lo2 = _job(prio=0), _job(prio=5), _job(prio=0)
    q.push(_rec(lo1, 0)); q.push(_rec(hi, 1)); q.push(_rec(lo2, 2))
    assert q.peek_priority() == 5
    order = [q.pop().job.job_id for _ in range(3)]
    assert order == [hi.job_id, lo1.job_id, lo2.job_id]
    assert q.pop() is None


def test_queue_requeue_preserves_position():
    """A preempted job re-enters ahead of later arrivals of equal prio."""
    q = PriorityJobQueue()
    first, second = _job(prio=1), _job(prio=1)
    q.push(_rec(first, 0)); q.push(_rec(second, 1))
    got = q.pop()
    assert got.job.job_id == first.job_id
    q.push(got)                     # preemption path: same record, same seq
    assert q.pop().job.job_id == first.job_id


def test_queue_cancel():
    q = PriorityJobQueue()
    a, b = _job(), _job()
    q.push(_rec(a, 0)); q.push(_rec(b, 1))
    assert q.cancel(a.job_id)
    assert not q.cancel("nope")
    assert q.pop().job.job_id == b.job_id
    assert len(q) == 0


# --------------------------------------------------------------------------
# footprint estimation + placement
# --------------------------------------------------------------------------

def test_footprint_small_job_resident():
    fp = estimate_job_footprint(_job("cgls"), _mem(1024))
    assert not fp.streams
    # 3 volume copies + 3 projection-set copies for CGLS at 16^3 / 12 angles
    assert fp.bytes_on_device == 3 * 16**3 * 4 + 3 * 12 * 16 * 16 * 4


def test_footprint_oversized_job_streams():
    job = ReconJob("ossart", BIG_GEO, BIG_ANGLES, lambda: None)
    fp = estimate_job_footprint(job, _mem(220))
    assert fp.streams
    assert fp.bytes_on_device <= _mem(220).usable


def test_footprint_respects_forced_mode_and_hint():
    assert estimate_job_footprint(_job(mode="stream"), _mem(1024)).streams
    fp = estimate_job_footprint(_job(memory_hint_bytes=12345), _mem(1024))
    assert fp.bytes_on_device == 12345


def test_pool_placement_respects_budget():
    pool = DevicePool(n_devices=2, memory=_mem(100))
    cap = pool.memory.usable
    s1 = pool.best_fit(60 * KIB)
    pool.commit(s1, "a", 60 * KIB)
    s2 = pool.best_fit(60 * KIB)          # does not fit next to "a"
    assert s2 is not s1
    pool.commit(s2, "b", 60 * KIB)
    assert pool.best_fit(60 * KIB) is None   # pool full for this size
    assert pool.best_fit(cap - 60 * KIB) is not None  # small one still fits
    pool.release(s1, "a", 60 * KIB)
    assert pool.best_fit(60 * KIB) is s1
    assert s1.free_bytes == cap


def test_pool_spread_vs_pack():
    spread = DevicePool(n_devices=2, memory=_mem(100))
    a = spread.best_fit(10 * KIB); spread.commit(a, "a", 10 * KIB)
    assert spread.best_fit(10 * KIB) is not a      # least-loaded first
    pack = DevicePool(n_devices=2, memory=_mem(100), policy="pack")
    b = pack.best_fit(10 * KIB); pack.commit(b, "b", 10 * KIB)
    assert pack.best_fit(10 * KIB) is b            # tightest fit first


def test_scheduler_isolates_bad_tenants():
    sched = Scheduler(n_devices=1)
    with pytest.raises(ValueError, match="unknown algorithm"):
        sched.submit(_job("not-an-algorithm"))
    # a job whose init blows up (bad data ref) fails alone; the scheduler
    # keeps serving the healthy tenant
    bad = sched.submit(ReconJob("cgls", GEO, ANGLES,
                                lambda: 1 / 0, n_iter=2))
    good = sched.submit(_job("cgls", n_iter=2))
    sched.run()
    assert sched.records[bad].status is JobStatus.FAILED
    assert "init failed" in sched.records[bad].error
    assert sched.records[good].status is JobStatus.COMPLETED
    np.testing.assert_array_equal(sched.result(good), _mono("cgls", 2))


def test_scheduler_fails_never_fitting_job():
    sched = Scheduler(n_devices=1, memory=_mem(100))
    jid = sched.submit(_job("cgls", memory_hint_bytes=10 * 1024 * KIB))
    sched.run(max_quanta=2)
    rec = sched.records[jid]
    assert rec.status is JobStatus.FAILED
    assert "exceeds" in rec.error
    with pytest.raises(RuntimeError):
        sched.result(jid)


# --------------------------------------------------------------------------
# step-wise iterators == monolithic algorithms (bit-for-bit)
# --------------------------------------------------------------------------

_MONO_MEMO = {}


def _mono(alg, n_iter):
    if (alg, n_iter) in _MONO_MEMO:
        return _MONO_MEMO[(alg, n_iter)]
    _MONO_MEMO[(alg, n_iter)] = _mono_run(alg, n_iter)
    return _MONO_MEMO[(alg, n_iter)]


def _mono_run(alg, n_iter):
    if alg == "cgls":
        return np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=n_iter))
    if alg == "ossart":
        return np.asarray(ossart(PROJ, GEO, ANGLES, n_iter=n_iter,
                                 subset_size=4))
    if alg == "fista":
        return np.asarray(fista_tv(PROJ, GEO, ANGLES, n_iter=n_iter,
                                   tv_iters=3, L=100.0))
    if alg == "asd_pocs":
        return np.asarray(asd_pocs(PROJ, GEO, ANGLES, n_iter=n_iter,
                                   subset_size=4, tv_iters=3))
    raise AssertionError(alg)


_PARAMS = {"ossart": {"subset_size": 4},
           "fista": {"tv_iters": 3, "L": 100.0},   # fixed L skips power it.
           "asd_pocs": {"subset_size": 4, "tv_iters": 3}, "cgls": {}}


@pytest.mark.parametrize("alg", ["cgls", "ossart", "fista", "asd_pocs"])
def test_stepwise_matches_monolithic_bitwise(alg):
    n_iter = 2
    a = get_algorithm(alg)
    st = a.init(PROJ, GEO, ANGLES, **_PARAMS[alg])
    for _ in range(n_iter):
        st = a.step(st)
    got = np.asarray(a.finalize(st))
    np.testing.assert_array_equal(got, _mono(alg, n_iter))


# --------------------------------------------------------------------------
# preemption
# --------------------------------------------------------------------------

def test_preemption_prioritizes_urgent_job_and_preserves_result():
    # budget fits exactly one resident small job (84 KiB < 100 KiB < 168)
    sched = Scheduler(n_devices=1, memory=_mem(100))
    lo = sched.submit(_job("ossart", prio=0, n_iter=4,
                           params={"subset_size": 4}))
    sched.run(max_quanta=2)          # low-prio makes some progress
    assert sched.records[lo].iterations_done >= 1
    hi = sched.submit(_job("cgls", prio=9, n_iter=2))
    sched.run()
    rec_lo, rec_hi = sched.records[lo], sched.records[hi]
    assert rec_lo.preemptions >= 1
    assert rec_hi.end_time <= rec_lo.end_time
    assert sched.metrics.preemptions >= 1
    # both results bit-identical to uninterrupted monolithic runs
    np.testing.assert_array_equal(
        sched.result(lo), np.asarray(ossart(PROJ, GEO, ANGLES, n_iter=4,
                                            subset_size=4)))
    np.testing.assert_array_equal(sched.result(hi), _mono("cgls", 2))


def test_guard_drain_and_resume_with_lazy_data_ref():
    calls = []

    def ref():                       # lazy data ref, resolved at admission
        calls.append(1)
        return PROJ

    guard = PreemptionGuard(install_handler=False)
    sched = Scheduler(n_devices=1, guard=guard)
    jid = sched.submit(ReconJob("cgls", GEO, ANGLES, ref, n_iter=3))
    assert not calls                 # nothing resolved at submit time
    sched.run(max_quanta=1)
    assert calls == [1]
    guard.trigger()                  # host SIGTERM equivalent
    sched.run()
    rec = sched.records[jid]
    assert rec.status is JobStatus.PREEMPTED
    assert rec.checkpoint is not None
    sched.guard = None               # "restarted" host
    sched.run()
    assert rec.status is JobStatus.COMPLETED
    assert calls == [1, 1]           # re-resolved on re-admission
    np.testing.assert_array_equal(sched.result(jid), _mono("cgls", 3))


# --------------------------------------------------------------------------
# end-to-end: concurrent mixed-size serving
# --------------------------------------------------------------------------

def test_concurrent_mixed_size_jobs_match_solo_runs():
    """>= 3 jobs of mixed sizes share a small-memory pool concurrently;
    every result is numerically identical to a solo monolithic run."""
    big_proj = phantoms.sphere_projection_analytic(BIG_GEO, BIG_ANGLES)
    sched = Scheduler(n_devices=3, memory=_mem(220))
    jids = [
        sched.submit(_job("cgls", n_iter=2)),
        sched.submit(_job("ossart", n_iter=2, params={"subset_size": 4})),
        sched.submit(_job("cgls", n_iter=3)),
        sched.submit(ReconJob("ossart", BIG_GEO, BIG_ANGLES, big_proj,
                              n_iter=1, params={"subset_size": 16})),
    ]
    max_running = 0
    while not sched.idle:
        sched.step_quantum()
        max_running = max(max_running, len(sched.running))
    assert max_running >= 3          # genuinely concurrent
    recs = [sched.records[j] for j in jids]
    assert all(r.status is JobStatus.COMPLETED for r in recs)
    assert recs[3].streamed          # the big one went out-of-core
    assert len({r.device for r in recs[:3]}) > 1   # packed across devices

    np.testing.assert_array_equal(sched.result(jids[0]), _mono("cgls", 2))
    np.testing.assert_array_equal(sched.result(jids[1]),
                                  _mono("ossart", 2))
    np.testing.assert_array_equal(sched.result(jids[2]), _mono("cgls", 3))
    solo_big = np.asarray(ossart(big_proj, BIG_GEO, BIG_ANGLES, n_iter=1,
                                 subset_size=16))
    got_big = sched.result(jids[3])
    np.testing.assert_allclose(got_big, solo_big, rtol=2e-3, atol=2e-3)


