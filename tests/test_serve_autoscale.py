"""Elastic fleet autoscaling: scale-up/down from load, hysteresis (no
thrash under an oscillating load trace), scale-down drains that move
mid-progress jobs bit-identically, fleet-level durable snapshots
(kill -9 -> restore_fleet rebuilds membership + parked jobs), the
unlocked-executor-init admission path, and the recon CLI round trip
with --pods N + --snapshot-dir."""

import json
import os
import time

import numpy as np
import pytest

from repro.core import phantoms
from repro.core.algorithms import cgls, ossart
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.splitting import MemoryModel
from repro.serve import (Autoscaler, AutoscalePolicy, AsyncDriver,
                         JobStatus, MultiPodDriver, MultiPodScheduler,
                         Pod, PodSpec, ReconJob, Scheduler, ServeMetrics,
                         drain_pod, merge_metrics)
from repro.serve.steal import fleet_units, pod_load

GEO = ConeGeometry.nice(16)
ANGLES = circular_angles(12)
PROJ = phantoms.sphere_projection_analytic(GEO, ANGLES)

KIB = 1024


def _mem(kib=220, frac=1.0):
    return MemoryModel(device_bytes=kib * KIB, usable_fraction=frac)


def _job(alg="cgls", prio=0, n_iter=2, **kw):
    return ReconJob(alg, GEO, ANGLES, PROJ, n_iter=n_iter, priority=prio,
                    **kw)


def _pod(name, kib=220, devices=1):
    return Pod(PodSpec(name, n_devices=devices, memory=_mem(kib)))


def _policy(**kw):
    kw.setdefault("scale_up_backlog_seconds", 0.5)
    kw.setdefault("scale_down_backlog_seconds", 0.05)
    kw.setdefault("up_window_seconds", 0.0)
    kw.setdefault("down_window_seconds", 0.0)
    kw.setdefault("cooldown_seconds", 0.0)
    kw.setdefault("min_pods", 1)
    kw.setdefault("max_pods", 3)
    return AutoscalePolicy(**kw)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# --------------------------------------------------------------------------
# policy validation + basic elasticity
# --------------------------------------------------------------------------

def test_policy_validation():
    with pytest.raises(ValueError, match="band inverted"):
        AutoscalePolicy(scale_up_backlog_seconds=1.0,
                        scale_down_backlog_seconds=2.0)
    with pytest.raises(ValueError, match="min_pods"):
        AutoscalePolicy(min_pods=3, max_pods=1)
    with pytest.raises(ValueError, match="at least one PodSpec"):
        Autoscaler(MultiPodScheduler([_pod("p0")]), templates=[])
    # device-pinned templates would double-book physical devices when
    # instantiated repeatedly
    import jax
    with pytest.raises(ValueError, match="simulated"):
        Autoscaler(MultiPodScheduler([_pod("p0")]),
                   templates=[PodSpec("pinned",
                                      jax_devices=tuple(jax.devices()[:1]))])


def test_autoscaler_grows_and_shrinks_fleet_bit_identically(tmp_path):
    """Backlog on one seed pod grows the fleet from the template pool;
    once the work clears the surplus pods are drained + retired; every
    result matches the monolithic run.

    Deterministic by construction (no wall-clock coupling): the
    autoscaler runs on an injected FakeClock and is stepped explicitly
    between cooperative quanta, so the scale decisions depend only on
    the modeled backlog — a cold fleet prices 6 jobs x 4 iterations at
    the 1.0 s/unit fallback, far above the 0.5 s high watermark, so the
    first control step MUST scale up; an idle fleet models 0.0 backlog,
    below the 0.05 s low watermark, so the drain-and-retire steps MUST
    fire once the work clears."""
    clock = FakeClock()
    mps = MultiPodScheduler([_pod("seed")],
                            transfer_dir=str(tmp_path / "xfer"))
    asc = Autoscaler(mps, [PodSpec("burst", n_devices=1, memory=_mem())],
                     _policy(), clock=clock)
    jids = [mps.submit(_job(n_iter=4)) for _ in range(6)]
    ev = asc.step()
    assert ev is not None and ev.direction == "up", \
        "cold 24-unit modeled backlog did not cross the 0.5s watermark"
    rounds = 0
    while not mps.idle:
        for pod in mps.pods_snapshot():
            pod.scheduler.step_quantum()
        mps.steal_pass()           # the burst pod takes parked work
        clock.t += 1.0
        asc.step()
        rounds += 1
        assert rounds < 200, "fleet never finished the backlog"
    while len(mps.pods) > 1:       # idle: load 0.0 < 0.05 -> shrink
        clock.t += 1.0
        assert asc.step() is not None, \
            "idle fleet above min_pods refused to scale down"
    ups = [e for e in asc.events if e.direction == "up"]
    downs = [e for e in asc.events if e.direction == "down"]
    assert ups, "backlog never grew the fleet"
    assert downs, "idle fleet never shrank"
    assert len(mps.pods) >= 1 and mps.retired_pods
    want = np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=4))
    for j in jids:
        np.testing.assert_array_equal(mps.result(j), want)
    s = mps.summary()
    assert s["scale_up_events"] == len(ups)
    assert s["scale_down_events"] == len(downs)
    assert s["pods_online_peak"] >= 2
    assert s["pod_seconds"] > 0
    assert s["completed"] == len(jids)      # retired pods' counters kept


def test_add_pod_rejects_duplicate_names():
    mps = MultiPodScheduler([_pod("p0"), _pod("p1")])
    with pytest.raises(ValueError, match="already used"):
        mps.add_pod(_pod("p0"))
    mps.remove_pod("p1")                    # idle: retires fine
    with pytest.raises(ValueError, match="already used"):
        mps.add_pod(_pod("p1"))             # retired names stay reserved


def test_remove_pod_refuses_nonempty():
    mps = MultiPodScheduler([_pod("p0"), _pod("p1")])
    mps.submit(_job(n_iter=2), pod="p0")
    with pytest.raises(ValueError, match="still holds work"):
        mps.remove_pod("p0")
    mps.run()


def test_scale_up_for_job_that_fits_no_live_pod(tmp_path):
    """The fits_nowhere_bytes signal: a submission too big for every
    live pod asks the autoscaler for a template pod that can hold it,
    instead of taking the canonical budget failure."""
    mps = MultiPodScheduler([_pod("small", kib=220)],
                            transfer_dir=str(tmp_path / "xfer"))
    asc = Autoscaler(mps, [PodSpec("big", n_devices=1,
                                   memory=_mem(8 * KIB))],
                     _policy())
    jid = mps.submit(_job(n_iter=1, memory_hint_bytes=5000 * KIB))
    assert mps.owner(jid).name.startswith("big-as")
    mps.run(autoscaler=asc)
    assert mps.record(jid).status is JobStatus.COMPLETED
    # without an autoscaler the same submission fails with the budget
    solo = MultiPodScheduler([_pod("small", kib=220)])
    bad = solo.submit(_job(n_iter=1, memory_hint_bytes=5000 * KIB))
    solo.run(max_rounds=2)
    assert solo.record(bad).status is JobStatus.FAILED


# --------------------------------------------------------------------------
# hysteresis: an oscillating load trace must not thrash the fleet
# --------------------------------------------------------------------------

def test_cooldown_bounds_scale_events_under_oscillating_load(tmp_path):
    """Load flips high/low every 0.5s for 50s; the 10s cooldown bounds
    the scale events to span/cooldown + 1 instead of one per flip."""
    clock = FakeClock()
    mps = MultiPodScheduler([_pod("seed")],
                            transfer_dir=str(tmp_path / "xfer"))
    loads = iter([10.0, 0.0] * 100)
    asc = Autoscaler(mps, [PodSpec("burst", n_devices=1, memory=_mem())],
                     _policy(cooldown_seconds=10.0, max_pods=4),
                     clock=clock, load_fn=lambda pods: next(loads))
    flips = 0
    while clock.t < 50.0:
        asc.step()
        clock.t += 0.5
        flips += 1
    assert flips == 100
    assert len(asc.events) <= 50.0 / 10.0 + 1


def test_persistence_windows_suppress_flapping_signal(tmp_path):
    """With 2s persistence windows, a signal that never stays high or
    low for 2s produces zero scale events even with no cooldown."""
    clock = FakeClock()
    mps = MultiPodScheduler([_pod("seed")],
                            transfer_dir=str(tmp_path / "xfer"))
    loads = iter([10.0, 0.0] * 100)
    asc = Autoscaler(mps, [PodSpec("burst", n_devices=1, memory=_mem())],
                     _policy(up_window_seconds=2.0, down_window_seconds=2.0,
                             cooldown_seconds=0.0),
                     clock=clock, load_fn=lambda pods: next(loads))
    while clock.t < 50.0:
        asc.step()
        clock.t += 0.5
    assert asc.events == []
    # and a *persistent* high signal does scale up once the window passes
    asc2 = Autoscaler(mps, [PodSpec("burst2", n_devices=1, memory=_mem())],
                      _policy(up_window_seconds=2.0,
                              down_window_seconds=2.0),
                      clock=clock, load_fn=lambda pods: 10.0)
    t0 = clock.t
    while clock.t < t0 + 1.5:
        assert asc2.step() is None      # inside the window: no event yet
        clock.t += 0.5
    clock.t += 1.0
    ev = asc2.step()
    assert ev is not None and ev.direction == "up"


def test_hysteresis_window_resets_and_fires_at_exact_boundary(tmp_path):
    """Regression pinning the two window semantics the deflaked tests
    rely on: (a) a single dead-band sample RESETS the persistence
    window — a high signal interrupted every third second never fires,
    even though its cumulative high time is unbounded; (b) an
    uninterrupted signal fires at the first control step where
    ``now - window_start >= window`` (closed boundary), not one step
    later."""
    clock = FakeClock()
    mps = MultiPodScheduler([_pod("seed")],
                            transfer_dir=str(tmp_path / "xfer"))
    load = {"v": 10.0}
    asc = Autoscaler(mps, [PodSpec("burst", n_devices=1, memory=_mem())],
                     _policy(up_window_seconds=2.0, down_window_seconds=2.0,
                             cooldown_seconds=0.0, max_pods=2),
                     clock=clock, load_fn=lambda pods: load["v"])
    # (a) high-high-dip at 1s steps: without the reset, the window armed
    # at t=0 would fire at t=2; with it, nothing ever fires because the
    # signal never persists 2 consecutive seconds
    for i in range(12):
        load["v"] = 0.3 if i % 3 == 2 else 10.0   # 0.3 = inside the band
        assert asc.step() is None, f"dipping signal scaled at sample {i}"
        clock.t += 1.0
    # (b) sustained high: armed at t0, still pending at t0+1, fires at
    # exactly t0+2
    load["v"] = 10.0
    t0 = clock.t
    assert asc.step() is None
    clock.t = t0 + 1.0
    assert asc.step() is None
    clock.t = t0 + 2.0
    ev = asc.step()
    assert ev is not None and ev.direction == "up" and ev.t == t0 + 2.0
    # same closed boundary on the way down
    load["v"] = 0.0
    t1 = clock.t + 1.0
    clock.t = t1
    assert asc.step() is None
    clock.t = t1 + 1.0
    assert asc.step() is None
    clock.t = t1 + 2.0
    ev = asc.step()
    assert ev is not None and ev.direction == "down" and ev.t == t1 + 2.0
    assert [p.name for p in mps.pods] == ["seed"]


# --------------------------------------------------------------------------
# scale-down drain: preempt-then-export, bit-identical on the survivor
# --------------------------------------------------------------------------

def test_scale_down_drains_mid_progress_job_bit_identically(tmp_path):
    """The least-loaded pod holds a job parked mid-progress; scale-down
    must move it (checkpoint and all) to a survivor, retire the pod, and
    the job must finish bit-identically to never having been drained."""
    p0, p1 = _pod("p0", kib=100), _pod("p1", kib=100)
    mps = MultiPodScheduler([p0, p1], steal=False,
                            transfer_dir=str(tmp_path / "xfer"))
    vic = mps.submit(_job("ossart", n_iter=6, params={"subset_size": 4}),
                     pod="p0")
    for _ in range(3):
        p0.scheduler.step_quantum()
    done_before = mps.record(vic).iterations_done
    assert done_before >= 1
    # keep p1 busier than p0 so p0 is the least-loaded victim
    other = [mps.submit(_job(n_iter=10), pod="p1") for _ in range(2)]
    p1.scheduler.step_quantum()
    unit, init = fleet_units([p0, p1])
    assert pod_load(p0.scheduler, 1, unit=unit, init=init) \
        < pod_load(p1.scheduler, 1, unit=unit, init=init)

    asc = Autoscaler(mps, [PodSpec("t", n_devices=1, memory=_mem(100))],
                     _policy(), load_fn=lambda pods: 0.0)   # force "down"
    ev = asc.step()
    assert ev is not None and ev.direction == "down" and ev.pod == "p0"
    assert asc.drained_jobs == [vic]
    assert [p.name for p in mps.pods] == ["p1"]
    assert vic in p1.scheduler.records
    assert mps.record(vic).iterations_done == done_before
    mps.run()
    np.testing.assert_array_equal(
        mps.result(vic),
        np.asarray(ossart(PROJ, GEO, ANGLES, n_iter=6, subset_size=4)))
    for j in other:
        assert mps.record(j).status is JobStatus.COMPLETED


def test_scale_down_aborts_when_job_cannot_move(tmp_path):
    """A lazy-data job with no resolver cannot be exported: the drain
    must abort cleanly — pod stays, admission resumes, nothing lost."""
    p0, p1 = _pod("p0", kib=100), _pod("p1", kib=100)
    mps = MultiPodScheduler([p0, p1], steal=False,
                            transfer_dir=str(tmp_path / "xfer"))
    hold = mps.submit(_job(n_iter=2), pod="p0")
    lazy = mps.submit(ReconJob("cgls", GEO, ANGLES, lambda: PROJ, n_iter=2),
                      pod="p0")
    p0.scheduler.admit()
    # load p1 heavier so the lazy-holding p0 is the scale-down victim
    for _ in range(3):
        mps.submit(_job(n_iter=8), pod="p1")
    asc = Autoscaler(mps, [PodSpec("t", n_devices=1, memory=_mem(100))],
                     _policy(), load_fn=lambda pods: 0.0)
    assert asc.step() is None
    assert asc.aborted_scale_downs == 1
    assert {p.name for p in mps.pods} == {"p0", "p1"}
    assert not p0.draining and not p0.scheduler.admission_paused
    mps.autoscaler = None       # stop retrying the doomed drain
    mps.run()
    for jid in (hold, lazy):
        assert mps.record(jid).status is JobStatus.COMPLETED


def test_drain_pod_moves_everything_and_respects_survivor_budget(tmp_path):
    """drain_pod empties a pod with queued + mid-progress work onto the
    survivor that can hold each job; a job no survivor can hold aborts
    with the pod intact."""
    p0, p1 = _pod("p0", kib=8 * KIB), _pod("p1", kib=8 * KIB)
    jids = [p0.scheduler.submit(_job(n_iter=3)) for _ in range(3)]
    p0.scheduler.step_quantum()
    moved = drain_pod(p0, [p1], str(tmp_path / "xfer"))
    assert sorted(moved) == sorted(jids)
    assert p0.scheduler.idle and p0.scheduler.admission_paused
    p1.scheduler.run()
    want = np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=3))
    for j in jids:
        np.testing.assert_array_equal(p1.scheduler.result(j), want)
    # survivor too small for the job: abort, victim keeps it
    big = Pod(PodSpec("big", n_devices=1, memory=_mem(8 * KIB)))
    tiny = Pod(PodSpec("tiny", n_devices=1, memory=_mem(100)))
    kept = big.scheduler.submit(_job(n_iter=1,
                                     memory_hint_bytes=5000 * KIB))
    with pytest.raises(RuntimeError, match="cannot move"):
        drain_pod(big, [tiny], str(tmp_path / "xfer2"))
    assert kept in big.scheduler.records
    assert not big.scheduler.admission_paused
    big.scheduler.run()
    assert big.scheduler.records[kept].status is JobStatus.COMPLETED


# --------------------------------------------------------------------------
# fleet-level durable snapshots: kill -9 -> restore_fleet
# --------------------------------------------------------------------------

def test_restore_fleet_requires_manifest(tmp_path):
    with pytest.raises(FileNotFoundError, match="fleet.json"):
        MultiPodScheduler.restore_fleet(str(tmp_path))


def test_kill9_then_restore_fleet_rebuilds_membership_and_jobs(tmp_path):
    """Kill -9 semantics: the process dies with no drain — all that
    survives is the manifest + the periodic snapshots.  restore_fleet
    must rebuild the autoscaled membership (seed + added pod) and every
    job, and the jobs must complete bit-identically."""
    root = str(tmp_path / "fleet")
    mps = MultiPodScheduler([_pod("seed")], snapshot_root=root,
                            transfer_dir=str(tmp_path / "xfer"))
    asc = Autoscaler(mps, [PodSpec("burst", n_devices=1, memory=_mem())],
                     _policy(scale_down_backlog_seconds=1e-9))
    jids = [mps.submit(_job(n_iter=5)) for _ in range(4)]
    assert asc.step().direction == "up"      # autoscaled membership
    assert mps.snapshot_fleet() == len(jids)
    mps.autoscaler = None                    # freeze membership for the
    mps.run(max_rounds=2)                    # kill window; real progress
    mps.snapshot_fleet()                     # ...parked state persisted
    mps.run(max_rounds=1)                    # progress PAST the snapshot
    del mps                                  # kill -9: nothing drained

    restored = MultiPodScheduler.restore_fleet(root)
    assert {p.name for p in restored.pods} == {"seed", "burst-as0"}
    assert restored.snapshot_root == root
    assert set(restored.restored_jobs) == set(jids)
    restored.run()
    want = np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=5))
    for j in jids:
        assert restored.record(j).status is JobStatus.COMPLETED
        np.testing.assert_array_equal(restored.result(j), want)


def test_drain_fleet_restore_roundtrip_threaded(tmp_path):
    """SIGTERM path under the threaded driver: drain_fleet parks +
    persists everything; restore_fleet + MultiPodDriver completes
    bit-identically."""
    root = str(tmp_path / "fleet")
    mps = MultiPodScheduler([_pod("p0"), _pod("p1")], snapshot_root=root,
                            transfer_dir=str(tmp_path / "xfer"))
    jids = [mps.submit(_job(n_iter=5)) for _ in range(3)]
    drv = MultiPodDriver(mps)
    drv.start()

    def progress():
        # a job mid-steal is briefly in no scheduler: skip it this poll
        best = 0
        for j in jids:
            try:
                best = max(best, mps.record(j).iterations_done)
            except KeyError:
                pass
        return best

    deadline = time.monotonic() + 120
    while progress() < 1 and time.monotonic() < deadline:
        time.sleep(0.01)
    drv.stop()
    done_before = {j: np.asarray(mps.result(j)) for j in jids
                   if mps.record(j).status is JobStatus.COMPLETED}
    parked = mps.drain_fleet()
    assert parked + len(done_before) >= 1

    restored = MultiPodScheduler.restore_fleet(root)
    assert {p.name for p in restored.pods} == {"p0", "p1"}
    # completed jobs are terminal tombstones on disk, never resurrected
    assert set(restored.restored_jobs) == set(jids) - set(done_before)
    MultiPodDriver(restored).run(timeout=300)
    want = np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=5))
    for j in jids:
        got = (done_before[j] if j in done_before
               else np.asarray(restored.result(j)))
        np.testing.assert_array_equal(got, want)


def test_scale_up_recheck_cap_under_fleet_lock(tmp_path):
    """_scale_up re-validates max_pods under the fleet lock: two racing
    scale-up paths (control thread + submit-time fits-nowhere hook) must
    not overshoot the cap."""
    mps = MultiPodScheduler([_pod("p0"), _pod("p1")],
                            transfer_dir=str(tmp_path / "xfer"))
    asc = Autoscaler(mps, [PodSpec("t", n_devices=1, memory=_mem())],
                     _policy(max_pods=2))
    assert asc._scale_up(0.0, 1.0) is None        # already at the cap
    assert len(mps.pods) == 2 and asc.events == []


def test_restore_fleet_twice_keeps_homes(tmp_path):
    """The manifest's homes map survives a restore (the ctor's early
    manifest rewrite must not wipe it), so home() still answers after a
    second crash/restore cycle."""
    root = str(tmp_path / "fleet")
    mps = MultiPodScheduler([_pod("p0"), _pod("p1")], snapshot_root=root,
                            transfer_dir=str(tmp_path / "xfer"))
    jid = mps.submit(_job(n_iter=4))
    first_home = mps.home(jid)
    mps.run(max_rounds=1)
    mps.drain_fleet()

    r1 = MultiPodScheduler.restore_fleet(root)
    assert r1.home(jid) == first_home
    del r1                                         # second kill, no drain
    r2 = MultiPodScheduler.restore_fleet(root)
    assert r2.home(jid) == first_home
    r2.run()
    assert r2.record(jid).status is JobStatus.COMPLETED


# --------------------------------------------------------------------------
# unlocked executor init: a slow compile must not stall other slots
# --------------------------------------------------------------------------

def test_slow_init_does_not_stall_running_jobs(monkeypatch, tmp_path):
    """Regression for init-inside-the-lock: while one job's executor
    init (compile) sleeps, an already-running job on another slot must
    keep stepping to completion instead of blocking on the scheduler
    lock for the whole compile."""
    from repro.serve.executor import JobExecutor
    warm = Scheduler(n_devices=1, memory=_mem())   # compile the operator
    warm.submit(_job(n_iter=1))
    warm.run()

    orig = JobExecutor.start
    slow_ids = set()

    def maybe_slow_start(self, checkpoint=None):
        if self.job.job_id in slow_ids:
            time.sleep(2.0)
        return orig(self, checkpoint=checkpoint)

    monkeypatch.setattr(JobExecutor, "start", maybe_slow_start)
    sched = Scheduler(n_devices=2, memory=_mem())
    fast = sched.submit(_job(prio=0, n_iter=4))
    driver = AsyncDriver(sched)
    driver.start()
    deadline = time.monotonic() + 60
    while (sched.records[fast].status is not JobStatus.RUNNING
           and time.monotonic() < deadline):
        time.sleep(0.005)
    t0 = time.monotonic()
    slow = _job(prio=5, n_iter=1)
    slow_ids.add(slow.job_id)
    sched.submit(slow)                    # init sleeps 2s off-lock
    assert driver.wait(timeout=120)
    driver.stop()
    fast_rec, slow_rec = sched.records[fast], sched.records[slow.job_id]
    assert fast_rec.status is JobStatus.COMPLETED
    assert slow_rec.status is JobStatus.COMPLETED
    # the fast job finished while the slow init was still sleeping
    assert fast_rec.end_time - t0 < 1.5, \
        "running job stalled behind a slow executor init"


def test_idle_accounts_for_inflight_admissions(monkeypatch):
    """A job mid-init is in neither the queue nor `running`; idle must
    still be False or a fleet driver would stop with the job lost."""
    from repro.serve.executor import JobExecutor
    orig = JobExecutor.start
    entered = []

    def slow_start(self, checkpoint=None):
        entered.append(time.monotonic())
        time.sleep(0.5)
        return orig(self, checkpoint=checkpoint)

    monkeypatch.setattr(JobExecutor, "start", slow_start)
    sched = Scheduler(n_devices=1, memory=_mem())
    jid = sched.submit(_job(n_iter=1))
    import threading
    t = threading.Thread(target=sched.admit)
    t.start()
    while not entered:
        time.sleep(0.005)
    assert not sched.idle                 # mid-init: not done
    t.join()
    sched.run()
    assert sched.records[jid].status is JobStatus.COMPLETED


# --------------------------------------------------------------------------
# fleet gauges in merge_metrics
# --------------------------------------------------------------------------

def test_merge_metrics_preserves_fleet_gauges():
    a = ServeMetrics(scale_up_events=2, scale_down_events=1,
                     pod_seconds=10.0, pods_online=[(1.0, 1), (3.0, 2)])
    b = ServeMetrics(scale_up_events=1, pod_seconds=5.0,
                     pods_online=[(2.0, 3)])
    m = merge_metrics([a, b])
    assert m.scale_up_events == 3 and m.scale_down_events == 1
    assert m.pod_seconds == 15.0
    assert m.pods_online == [(1.0, 1), (2.0, 3), (3.0, 2)]   # chronological
    s = m.summary()
    assert s["pods_online_peak"] == 3
    assert s["pod_seconds"] == 15.0


# --------------------------------------------------------------------------
# recon CLI: --pods N with --snapshot-dir (round trip)
# --------------------------------------------------------------------------

def test_recon_cli_pods_with_snapshot_dir_completes(tmp_path):
    """The former ValueError path: --pods 2 + --snapshot-dir must now
    run end to end and leave a fleet manifest behind."""
    from repro.launch.recon import reconstruct
    snap = str(tmp_path / "snap")
    rec, rel = reconstruct("cgls", n=16, n_angles=12, iters=2, pods=2,
                           device_bytes=220 * KIB, verbose=False,
                           snapshot_dir=snap)
    assert rec is not None and rel < 1.0
    assert os.path.isfile(os.path.join(snap, "fleet.json"))


def test_recon_cli_resumes_interrupted_fleet_bit_identically(tmp_path):
    """Round trip: a fleet interrupted mid-run (drained durably) is
    restored by re-running the CLI entry point with the same
    --snapshot-dir, and the finished volume is bit-identical to an
    uninterrupted reconstruction of the same dataset."""
    from repro.data import make_ct_dataset
    from repro.launch.recon import reconstruct
    snap = str(tmp_path / "snap")
    geo = ConeGeometry.nice(16)
    vol, angles, proj = make_ct_dataset(geo, 12)
    mem = MemoryModel(device_bytes=220 * KIB)
    mps = MultiPodScheduler(
        [Pod(PodSpec(f"pod{i}", n_devices=1, memory=mem))
         for i in range(2)],
        snapshot_root=snap, transfer_dir=str(tmp_path / "xfer"))
    jid = mps.submit(ReconJob("cgls", geo, angles, proj, n_iter=5))
    mps.run(max_rounds=2)                    # partial progress
    assert 0 < mps.record(jid).iterations_done < 5
    mps.drain_fleet()                        # the SIGTERM park
    del mps

    rec, _ = reconstruct("cgls", n=16, n_angles=12, iters=5, pods=2,
                         device_bytes=220 * KIB, verbose=False,
                         snapshot_dir=snap)
    want = np.asarray(cgls(proj, geo, angles, n_iter=5))
    np.testing.assert_array_equal(np.asarray(rec), want)


# --------------------------------------------------------------------------
# _next_pod error discipline + manifest writes outside the fleet lock
# --------------------------------------------------------------------------

def test_scale_up_surfaces_non_collision_errors(tmp_path):
    """Regression: _next_pod used to retry *every* ValueError forever —
    a template the Pod constructor rejects (here: a bogus placement
    policy) spun an infinite loop inside the fleet lock, wedging every
    submit/steal/snapshot in the process.  Only name collisions retry;
    anything else must propagate with the fleet lock released."""
    mps = MultiPodScheduler([_pod("seed")],
                            transfer_dir=str(tmp_path / "xfer"))
    asc = Autoscaler(mps, [PodSpec("bad", n_devices=1, memory=_mem(),
                                   placement="bogus")],
                     _policy())
    with pytest.raises(ValueError, match="placement"):
        asc._scale_up(0.0, 1.0)
    assert [p.name for p in mps.pods] == ["seed"]
    assert asc.events == []
    # the fleet lock must be free again (the old spin held it forever)
    assert mps._fleet_lock.acquire(timeout=1)
    mps._fleet_lock.release()
    # and the fleet still serves
    jid = mps.submit(_job(n_iter=1))
    mps.autoscaler = None
    mps.run()
    assert mps.record(jid).status is JobStatus.COMPLETED


def test_scale_up_still_retries_name_collisions(tmp_path):
    """The one ValueError that *should* retry: a name already used (e.g.
    re-seeded counter after a fleet restore) just advances the counter."""
    mps = MultiPodScheduler([_pod("seed"), _pod("burst-as0")],
                            transfer_dir=str(tmp_path / "xfer"))
    asc = Autoscaler(mps, [PodSpec("burst", n_devices=1, memory=_mem())],
                     _policy(max_pods=4))
    ev = asc._scale_up(0.0, 1.0)
    assert ev is not None and ev.pod == "burst-as1"
    assert {p.name for p in mps.pods} == {"seed", "burst-as0", "burst-as1"}


def test_scale_up_picks_template_by_queued_footprint_fit(tmp_path):
    """Heterogeneous template pool: a backlog-triggered scale-up picks
    the template by queued-job footprint fit, not by cycling order —
    ties break toward the smaller pod, and a job only one template can
    hold forces that template regardless of its position."""
    # tie case: small queued jobs fit both templates, so the smaller
    # template must win even though cycling would instantiate "big"
    # (index 0) first
    mps = MultiPodScheduler([_pod("seed", kib=220)],
                            transfer_dir=str(tmp_path / "xfer"))
    asc = Autoscaler(mps,
                     [PodSpec("big", n_devices=1, memory=_mem(8 * KIB)),
                      PodSpec("small", n_devices=1, memory=_mem(220))],
                     _policy(max_pods=4), load_fn=lambda pods: 10.0)
    assert asc._pick_template() is None    # empty queue: cycling fallback
    mps.pods[0].scheduler.pause_admission()
    jids = [mps.submit(_job(n_iter=1), pod="seed") for _ in range(3)]
    ev = asc.step()
    assert ev is not None and ev.direction == "up"
    assert ev.pod.startswith("small-as"), \
        "cycling order (big first) overrode the footprint fit"

    # fit-dominance case: the one queued job only fits the big template,
    # which sits *after* "small" in cycling order
    mps2 = MultiPodScheduler([_pod("seed", kib=8 * KIB)],
                             transfer_dir=str(tmp_path / "xfer2"))
    asc2 = Autoscaler(mps2,
                      [PodSpec("small", n_devices=1, memory=_mem(220)),
                       PodSpec("big", n_devices=1, memory=_mem(8 * KIB))],
                      _policy(max_pods=4), load_fn=lambda pods: 10.0)
    mps2.pods[0].scheduler.pause_admission()
    big_jid = mps2.submit(_job(n_iter=1, memory_hint_bytes=5000 * KIB),
                          pod="seed")
    ev2 = asc2.step()
    assert ev2 is not None and ev2.pod.startswith("big-as"), \
        "cycling (small first) beat the only template that fits"

    # the parked jobs still complete once admission resumes
    for m in (mps, mps2):
        m.autoscaler = None
        for p in m.pods:
            p.scheduler.resume_admission()
        m.run()
    for j in jids:
        assert mps.record(j).status is JobStatus.COMPLETED
    assert mps2.record(big_jid).status is JobStatus.COMPLETED


def test_scale_up_writes_manifest_outside_fleet_lock(tmp_path, monkeypatch):
    """Regression: the autoscaler's scale-up used to write fleet.json
    while holding the re-entrant fleet lock, serializing every submit
    behind disk I/O.  The write is now deferred to after the last lock
    exit: during the actual manifest write, another thread must be able
    to take the fleet lock."""
    import threading
    import repro.serve.pool as pool_mod
    root = str(tmp_path / "fleet")
    mps = MultiPodScheduler([_pod("seed")], snapshot_root=root,
                            transfer_dir=str(tmp_path / "xfer"))
    asc = Autoscaler(mps, [PodSpec("burst", n_devices=1, memory=_mem())],
                     _policy())
    orig_write = pool_mod._atomic_write_json
    probes = []

    def probing_write(path, payload):
        if path.endswith("fleet.json"):
            def probe():
                got = mps._fleet_lock.acquire(timeout=5)
                if got:
                    mps._fleet_lock.release()
                probes.append(got)
            t = threading.Thread(target=probe)
            t.start()
            t.join()
        return orig_write(path, payload)

    monkeypatch.setattr(pool_mod, "_atomic_write_json", probing_write)
    ev = asc._scale_up(0.0, 1.0)
    assert ev is not None and ev.pod == "burst-as0"
    assert probes and all(probes), \
        "fleet lock held across the manifest disk write"
    with open(os.path.join(root, "fleet.json")) as f:
        manifest = json.load(f)
    assert {p["name"] for p in manifest["pods"]} == {"seed", "burst-as0"}


# --------------------------------------------------------------------------
# pre-warm: a scaled-up pod builds the queued jobs' operators in its
# lead window (deterministic: injected clock, no wall-time coupling)
# --------------------------------------------------------------------------

def test_scale_up_prewarms_operator_cache(tmp_path):
    """With policy.prewarm the scale-up itself populates the shared
    executor operator cache with the queued jobs' operators (deduped by
    acquisition), before any scheduler quantum runs on the new pod."""
    from repro.serve.executor import (clear_operator_cache,
                                      operator_cache_keys)
    clock = FakeClock()
    mps = MultiPodScheduler([_pod("seed")],
                            transfer_dir=str(tmp_path / "xfer"))
    asc = Autoscaler(mps, [PodSpec("burst", n_devices=1, memory=_mem())],
                     _policy(prewarm=True), clock=clock)
    clear_operator_cache()
    jids = [mps.submit(_job(n_iter=2)) for _ in range(4)]
    assert operator_cache_keys() == (), \
        "submission alone must not build operators"
    ev = asc.step()
    assert ev is not None and ev.direction == "up"
    keys = operator_cache_keys()
    # 4 identical acquisitions dedupe to one warmed operator
    assert len(keys) == 1, f"prewarm built {len(keys)} operators, wanted 1"
    # the fleet then completes normally and the results are unchanged
    rounds = 0
    while not mps.idle:
        for pod in mps.pods_snapshot():
            pod.scheduler.step_quantum()
        mps.steal_pass()
        clock.t += 1.0
        asc.step()
        rounds += 1
        assert rounds < 200
    want = np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=2))
    for j in jids:
        np.testing.assert_array_equal(mps.result(j), want)


def test_scale_up_without_prewarm_leaves_cache_cold(tmp_path):
    """Default policy (prewarm=False): the scale-up must not touch the
    operator cache — warming is opt-in."""
    from repro.serve.executor import (clear_operator_cache,
                                      operator_cache_keys)
    clock = FakeClock()
    mps = MultiPodScheduler([_pod("seed")],
                            transfer_dir=str(tmp_path / "xfer"))
    asc = Autoscaler(mps, [PodSpec("burst", n_devices=1, memory=_mem())],
                     _policy(), clock=clock)
    clear_operator_cache()
    for _ in range(4):
        mps.submit(_job(n_iter=2))
    ev = asc.step()
    assert ev is not None and ev.direction == "up"
    assert operator_cache_keys() == ()
    clear_operator_cache()
