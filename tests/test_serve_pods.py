"""Multi-pod serving: pod construction from meshes, mesh-aware routing,
work stealing (bit-identical stolen resume), the threaded fleet driver,
fleet metrics, and the Scheduler.restore error paths (lazy refs, stale
terminal specs, truncated no-COMMIT snapshots)."""

import json
import os
import shutil
import threading
import time

import numpy as np
import pytest

from repro.core import phantoms
from repro.core.algorithms import cgls, ossart
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.splitting import MemoryModel
from repro.serve import (JobStatus, MultiPodDriver, MultiPodScheduler, Pod,
                         PodSpec, ReconJob, Scheduler, StealPolicy,
                         merge_metrics, modeled_job_seconds, pods_from_mesh,
                         steal_pass)
from repro.serve.metrics import ServeMetrics

GEO = ConeGeometry.nice(16)
ANGLES = circular_angles(12)
PROJ = phantoms.sphere_projection_analytic(GEO, ANGLES)

BIG_GEO = ConeGeometry.nice(32)
BIG_ANGLES = circular_angles(16)

KIB = 1024


def _mem(kib, frac=1.0):
    return MemoryModel(device_bytes=kib * KIB, usable_fraction=frac)


def _job(alg="cgls", prio=0, n_iter=2, **kw):
    return ReconJob(alg, GEO, ANGLES, PROJ, n_iter=n_iter, priority=prio,
                    **kw)


def _pods(n=2, kib=220, devices=1):
    return [Pod(PodSpec(f"p{i}", n_devices=devices, memory=_mem(kib)))
            for i in range(n)]


# --------------------------------------------------------------------------
# pod construction
# --------------------------------------------------------------------------

def test_pods_from_mesh_groups_by_pod_axis():
    from repro.core.compat import make_mesh
    # CPU test rig has one device; a (1, 1)-shaped pod mesh still must
    # produce one pod per pod index with that pod's devices in its pool
    mesh = make_mesh((1, 1), ("pod", "data"))
    pods = pods_from_mesh(mesh, memory=_mem(220))
    assert len(pods) == 1
    assert pods[0].n_devices == 1
    assert pods[0].pool.slots[0].jax_device is not None


def test_pods_from_mesh_without_pod_axis_is_single_pod():
    from repro.launch.mesh import make_host_mesh
    pods = pods_from_mesh(make_host_mesh(), memory=_mem(220))
    assert len(pods) == 1
    import jax
    assert pods[0].n_devices == jax.local_device_count()


def test_pod_device_groups_splits_leading_axis():
    from repro.launch.mesh import pod_device_groups

    class FakeMesh:
        axis_names = ("pod", "data")
        devices = np.arange(6).reshape(2, 3)

    groups = pod_device_groups(FakeMesh())
    assert [sorted(g) for g in groups] == [[0, 1, 2], [3, 4, 5]]
    FakeMesh.axis_names = ("data", "model")
    assert pod_device_groups(FakeMesh()) == [[0, 1, 2, 3, 4, 5]]


def test_multipod_rejects_duplicate_names_and_empty():
    with pytest.raises(ValueError, match="duplicate"):
        MultiPodScheduler(_pods(1) + _pods(1))
    with pytest.raises(ValueError, match="at least one"):
        MultiPodScheduler([])


# --------------------------------------------------------------------------
# mesh-aware routing
# --------------------------------------------------------------------------

def test_route_oversized_job_to_pod_with_cheaper_slab_plan():
    """A 32^3 volume streams in many slabs on a 220 KiB pod but is
    resident on an 8 MiB pod: the modeled makespan must route it to the
    big pod even though the small pod has more devices."""
    small = Pod(PodSpec("small", n_devices=3, memory=_mem(220)))
    big = Pod(PodSpec("big", n_devices=1, memory=_mem(8 * KIB)))
    mps = MultiPodScheduler([small, big], steal=False)
    big_proj = phantoms.sphere_projection_analytic(BIG_GEO, BIG_ANGLES)
    job = ReconJob("ossart", BIG_GEO, BIG_ANGLES, big_proj, n_iter=1,
                   params={"subset_size": 16})
    assert modeled_job_seconds(job, big) < modeled_job_seconds(job, small)
    jid = mps.submit(job)
    assert mps.owner(jid).name == "big"
    assert mps.home(jid) == "big"


def test_route_balances_load_across_equal_pods():
    """Equal pods: submissions spread by modeled backlog, not all on
    pod 0."""
    mps = MultiPodScheduler(_pods(2), steal=False)
    jids = [mps.submit(_job(n_iter=4)) for _ in range(4)]
    owners = {mps.owner(j).name for j in jids}
    assert owners == {"p0", "p1"}


def test_route_infeasible_everywhere_fails_on_largest_pod():
    mps = MultiPodScheduler(_pods(2, kib=100), steal=False)
    jid = mps.submit(_job(memory_hint_bytes=10 * 1024 * KIB))
    mps.run(max_rounds=2)
    rec = mps.record(jid)
    assert rec.status is JobStatus.FAILED
    assert "exceeds" in rec.error


def test_submit_pinned_overrides_routing():
    mps = MultiPodScheduler(_pods(2), steal=False)
    for pin, want in ((1, "p1"), ("p0", "p0")):
        jid = mps.submit(_job(), pod=pin)
        assert mps.owner(jid).name == want
    with pytest.raises(KeyError, match="no pod named"):
        mps.submit(_job(), pod="nope")


# --------------------------------------------------------------------------
# work stealing
# --------------------------------------------------------------------------

def test_steal_moves_parked_job_and_result_is_bit_identical(tmp_path):
    """All jobs pinned to pod 0 (static-partitioning imbalance): the
    idle pod must steal parked work through the manifest+COMMIT transfer
    and every final volume must equal the monolithic (unstolen) run."""
    mps = MultiPodScheduler(_pods(2), transfer_dir=str(tmp_path))
    jids = [mps.submit(_job(n_iter=3), pod=0) for _ in range(4)]
    mps.run()
    assert mps.stolen_jobs                       # something moved
    m = mps.metrics()
    assert m.stolen_out == m.stolen_in == len(mps.stolen_jobs)
    owners = {mps.owner(j).name for j in jids}
    assert owners == {"p0", "p1"}                # fleet actually balanced
    want = np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=3))
    for j in jids:
        assert mps.record(j).status is JobStatus.COMPLETED
        np.testing.assert_array_equal(mps.result(j), want)
    # successful imports consume their transfer copies (no disk leak,
    # nothing a later restore over the transfer dir could resurrect)
    for jid in mps.stolen_jobs:
        assert not os.path.exists(os.path.join(str(tmp_path), "jobs", jid))


def test_steal_preempted_job_resumes_bit_identically_on_thief(tmp_path):
    """A job parked *mid-progress* (preempted with a step-wise
    checkpoint) is stolen and must finish on the thief bit-identically —
    the checkpoint travels in the transfer."""
    pods = _pods(2, kib=100)                     # one resident job per pod
    victim = pods[0].scheduler
    a = victim.submit(_job("ossart", prio=0, n_iter=6,
                           params={"subset_size": 4}))
    victim.run(max_quanta=2)                     # make progress
    assert victim.records[a].iterations_done >= 1
    hi = victim.submit(_job(prio=9, n_iter=2))   # preempts + parks `a`
    victim.step_quantum()
    assert victim.records[a].status is JobStatus.PREEMPTED
    done_before = victim.records[a].iterations_done

    moved = steal_pass(pods, str(tmp_path))
    assert moved == [a]
    thief = pods[1].scheduler
    assert a in thief.records and a not in victim.records
    assert thief.records[a].iterations_done == done_before
    thief.run()
    victim.run()
    np.testing.assert_array_equal(
        thief.result(a),
        np.asarray(ossart(PROJ, GEO, ANGLES, n_iter=6, subset_size=4)))
    np.testing.assert_array_equal(
        victim.result(hi), np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=2)))


def test_steal_skips_lazy_jobs_without_data_refs(tmp_path):
    pods = _pods(2, kib=100)
    busy = pods[0].scheduler.submit(_job(n_iter=2))      # occupies the slot
    lazy = pods[0].scheduler.submit(
        ReconJob("cgls", GEO, ANGLES, lambda: PROJ, n_iter=2))
    pods[0].scheduler.admit()
    assert lazy in {r.job.job_id
                    for r in pods[0].scheduler.steal_candidates()}
    assert steal_pass(pods, str(tmp_path)) == []         # unresolvable ref
    moved = steal_pass(pods, str(tmp_path),
                       data_refs={lazy: lambda: PROJ})
    assert moved == [lazy]
    for p in pods:
        p.scheduler.run()
    np.testing.assert_array_equal(
        pods[1].scheduler.result(lazy),
        np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=2)))
    assert pods[0].scheduler.result(busy) is not None


def test_steal_respects_thief_budget(tmp_path):
    """A job that can never fit on the thief (even streamed) stays put."""
    big_pod = Pod(PodSpec("big", memory=_mem(8 * KIB)))
    tiny_pod = Pod(PodSpec("tiny", memory=_mem(100)))
    hold = big_pod.scheduler.submit(_job(memory_hint_bytes=7000 * KIB,
                                         n_iter=1))
    parked = big_pod.scheduler.submit(_job(memory_hint_bytes=5000 * KIB,
                                           n_iter=1))
    big_pod.scheduler.admit()
    assert steal_pass([big_pod, tiny_pod], str(tmp_path)) == []
    assert parked in big_pod.scheduler.records
    big_pod.scheduler.run()
    assert big_pod.scheduler.records[hold].status is JobStatus.COMPLETED


def test_steal_benefit_check_uses_thief_slab_cost(tmp_path):
    """A job resident on the loaded big-memory pod would stream in many
    slabs on the small idle thief; the slab-scaled cost (the same model
    routing uses) makes the move imbalance-inverting, so it must not
    happen even though the job technically fits the thief streamed."""
    from repro.serve.scheduler import modeled_step_passes
    big = Pod(PodSpec("big", memory=_mem(8 * KIB)))
    tiny = Pod(PodSpec("tiny", memory=_mem(220)))
    big_proj = phantoms.sphere_projection_analytic(BIG_GEO, BIG_ANGLES)
    # 4 iterations: unscaled the move always looks beneficial (cost 4 vs
    # a victim load of init + 4 + 2), slab-scaled (x3.5 on the tiny pod)
    # it always inverts — so the veto below can only come from the slab
    # multiplier, not from compile-time noise in the victim's init EMA
    job = ReconJob("ossart", BIG_GEO, BIG_ANGLES, big_proj, n_iter=4,
                   params={"subset_size": 16})
    assert modeled_step_passes(job, big.pool.memory) == 1.0
    passes_tiny = modeled_step_passes(job, tiny.pool.memory)
    assert passes_tiny > 3.0                     # streams in many slabs
    hold = big.scheduler.submit(_job(memory_hint_bytes=7800 * KIB,
                                     n_iter=2))
    parked = big.scheduler.submit(job)
    big.scheduler.admit()
    assert parked in {r.job.job_id
                      for r in big.scheduler.steal_candidates()}
    assert steal_pass([big, tiny], str(tmp_path)) == []
    assert parked in big.scheduler.records
    big.scheduler.run()
    assert big.scheduler.records[hold].status is JobStatus.COMPLETED


def test_steal_policy_thresholds(tmp_path):
    pods = _pods(2)
    for _ in range(3):
        pods[0].scheduler.submit(_job(n_iter=2))
    # imbalance below the threshold: nothing moves
    assert steal_pass(pods, str(tmp_path),
                      policy=StealPolicy(min_imbalance_seconds=1e9)) == []
    # keep-one policy: victim retains at least one parked job
    moved = steal_pass(pods, str(tmp_path),
                       policy=StealPolicy(min_victim_queue_after=2,
                                          max_jobs_per_pass=8))
    candidates = pods[0].scheduler.steal_candidates()
    assert len(candidates) >= 2
    assert len(moved) <= 1


def test_steal_import_failure_reclaims_job_on_victim(tmp_path, monkeypatch):
    """If the thief's import blows up after a successful export
    (transient transfer-mount error), the victim must re-adopt the job —
    a submitted job may never end up in no scheduler — and the steal
    accounting must cancel out."""
    from repro.serve.steal import steal_once
    pods = _pods(2, kib=100)
    victim, thief = pods
    hold = victim.scheduler.submit(_job(n_iter=2))
    parked = victim.scheduler.submit(_job(n_iter=2))
    victim.scheduler.admit()

    def broken_import(transfer_dir, job_id, data_refs=None):
        raise OSError("transfer mount gone")

    monkeypatch.setattr(thief.scheduler, "import_job", broken_import)
    assert steal_once(victim, thief, str(tmp_path)) is None
    assert parked in victim.scheduler.records       # reclaimed
    m = victim.scheduler.metrics
    assert m.stolen_out == 0 and m.stolen_in == 0
    victim.scheduler.run()
    want = np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=2))
    for jid in (hold, parked):
        np.testing.assert_array_equal(victim.scheduler.result(jid), want)


def test_route_and_steal_do_not_favor_warm_pod_unit_skew(tmp_path):
    """A warm pod's real-seconds EMA must not make its backlog look
    cheaper than an idle cold pod priced in 1.0 model units: the fleet
    comparisons share one unit scale, so the idle pod wins routing and
    is never selected as the steal victim."""
    from repro.serve.steal import fleet_units, pod_load
    pods = _pods(2)
    warm, cold = pods
    for _ in range(2):                       # warm up pod 0's EMAs
        warm.scheduler.submit(_job(n_iter=2))
    warm.scheduler.run()
    assert warm.scheduler.step_seconds_ema is not None
    assert cold.scheduler.step_seconds_ema is None
    # load the warm pod with parked work
    held = [warm.scheduler.submit(_job(n_iter=4)) for _ in range(4)]
    warm.scheduler.admit()
    unit, init = fleet_units(pods)
    assert pod_load(warm.scheduler, 1, unit=unit, init=init) \
        > pod_load(cold.scheduler, 1, unit=unit, init=init)
    # routing: the next submission must go to the idle cold pod
    mps = MultiPodScheduler(pods, transfer_dir=str(tmp_path))
    routed = mps.submit(_job(n_iter=2))
    assert mps.owner(routed).name == cold.name
    # stealing: the cold idle pod must be the thief, never the victim
    moved = mps.steal_pass()
    for jid in moved:
        assert jid in cold.scheduler.records
    mps.run()
    assert all(mps.record(j).status is JobStatus.COMPLETED
               for j in held + [routed])


def test_export_job_refuses_running_and_unknown(tmp_path):
    sched = Scheduler(n_devices=1, memory=_mem(1024))
    jid = sched.submit(_job(n_iter=4))
    sched.admit()                                # now running, not parked
    assert not sched.export_job(jid, str(tmp_path))
    assert not sched.export_job("nope", str(tmp_path))
    sched.run()
    assert sched.records[jid].status is JobStatus.COMPLETED


def test_transfer_dir_may_not_alias_snapshot_dir(tmp_path):
    """Hand-offs through the durable-snapshot directory would race the
    periodic snapshot's stale-out pass (it treats any on-disk copy of a
    job it no longer owns as stale) — refused up front at both layers."""
    snap = str(tmp_path / "snap")
    sched = Scheduler(n_devices=1, memory=_mem(100), snapshot_dir=snap)
    sched.submit(_job(n_iter=1))
    parked = sched.submit(_job(n_iter=1))
    sched.admit()
    with pytest.raises(ValueError, match="aliases"):
        sched.export_job(parked, snap)
    assert parked in sched.records               # nothing was exported
    pod = Pod(PodSpec("p0", memory=_mem(100)), snapshot_dir=snap)
    with pytest.raises(ValueError, match="aliases"):
        MultiPodScheduler([pod, Pod(PodSpec("p1", memory=_mem(100)))],
                          transfer_dir=snap)
    sched.run()


def test_export_stales_out_own_snapshot(tmp_path):
    """After a steal, a restart of the *victim* must not resurrect the
    exported job (it would run twice across the fleet)."""
    snap = str(tmp_path / "snap")
    transfer = str(tmp_path / "transfer")
    sched = Scheduler(n_devices=1, memory=_mem(100), snapshot_dir=snap)
    busy = sched.submit(_job(n_iter=2))
    parked = sched.submit(_job(n_iter=2))
    sched.admit()
    # parked jobs only: this test is about the *export* stale-out, so
    # keep the running job off disk (live snapshots are covered by
    # tests/test_serve_zero_loss.py)
    assert sched.snapshot(snap, include_running=False) == 1
    assert sched.export_job(parked, transfer)
    assert Scheduler(n_devices=1).restore(snap) == 0
    thief = Scheduler(n_devices=1, memory=_mem(100))
    thief.import_job(transfer, parked)
    thief.run()
    sched.run()
    np.testing.assert_array_equal(
        thief.result(parked), np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=2)))
    assert sched.records[busy].status is JobStatus.COMPLETED


def test_import_job_rejects_duplicates_and_missing(tmp_path):
    a = Scheduler(n_devices=1, memory=_mem(100))
    b = Scheduler(n_devices=1, memory=_mem(100))
    hold = a.submit(_job(n_iter=1))
    parked = a.submit(_job(n_iter=1))
    a.admit()
    assert a.export_job(parked, str(tmp_path))
    # keep a second copy: two thieves racing the same transfer dir
    racer_dir = str(tmp_path / "racer")
    shutil.copytree(str(tmp_path), racer_dir)
    b.import_job(str(tmp_path), parked)
    # consumed on success: re-import of the same dir finds nothing
    with pytest.raises(ValueError, match="no resumable job"):
        b.import_job(str(tmp_path), parked)
    # a raced duplicate of an id the thief already adopted is refused
    with pytest.raises(ValueError, match="already known"):
        b.import_job(racer_dir, parked)
    with pytest.raises((ValueError, OSError)):
        b.import_job(str(tmp_path), "never-exported")
    a.run(); b.run()
    assert a.records[hold].status is JobStatus.COMPLETED
    assert b.records[parked].status is JobStatus.COMPLETED


# --------------------------------------------------------------------------
# threaded fleet driver
# --------------------------------------------------------------------------

def test_multipod_driver_steals_and_matches_solo_runs(tmp_path):
    mps = MultiPodScheduler(_pods(2), transfer_dir=str(tmp_path))
    jids = [mps.submit(_job(n_iter=3), pod=0) for _ in range(6)]
    MultiPodDriver(mps).run(timeout=300)
    assert mps.idle
    want = np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=3))
    for j in jids:
        assert mps.record(j).status is JobStatus.COMPLETED
        np.testing.assert_array_equal(mps.result(j), want)
    s = mps.summary()
    assert s["completed"] == 6
    assert s["submitted"] == 6                   # steals don't double-count
    assert s["stolen_in"] == s["stolen_out"] == len(mps.stolen_jobs)


def test_multipod_driver_surfaces_pod_errors(monkeypatch, tmp_path):
    mps = MultiPodScheduler(_pods(2), transfer_dir=str(tmp_path))
    mps.submit(_job(n_iter=50), pod=0)

    def broken_pass():
        raise OSError("transfer filesystem gone")

    monkeypatch.setattr(mps, "steal_pass", broken_pass)
    with pytest.raises(RuntimeError, match="internal error"):
        MultiPodDriver(mps).run(timeout=120)


# --------------------------------------------------------------------------
# fleet metrics
# --------------------------------------------------------------------------

def test_merge_metrics_sums_counters_and_spans_walls():
    a = ServeMetrics(submitted=3, completed=2, stolen_out=1, steps=5,
                     step_seconds=[0.1] * 5, latencies=[1.0, 2.0],
                     queue_waits=[0.1, 0.2], wall_start=10.0, wall_end=14.0)
    b = ServeMetrics(submitted=1, completed=2, stolen_in=1, steps=2,
                     step_seconds=[0.2] * 2, latencies=[3.0],
                     queue_waits=[0.3], wall_start=11.0, wall_end=16.0)
    m = merge_metrics([a, b])
    assert m.submitted == 4 and m.completed == 4 and m.steps == 7
    assert m.stolen_out == 1 and m.stolen_in == 1
    assert m.wall_start == 10.0 and m.wall_end == 16.0
    assert m.wall_seconds == 6.0
    assert len(m.latencies) == 3 and len(m.step_seconds) == 7


def test_fleet_summary_has_per_pod_breakdown(tmp_path):
    mps = MultiPodScheduler(_pods(2), transfer_dir=str(tmp_path))
    mps.submit(_job(n_iter=1), pod=0)
    mps.run()
    s = mps.summary()
    assert set(s["pods"]) == {"p0", "p1"}
    assert s["pods"]["p0"]["completed"] + s["pods"]["p1"]["completed"] == 1
    assert "jobs_stolen" in s


# --------------------------------------------------------------------------
# Scheduler.restore error paths (snapshot trust)
# --------------------------------------------------------------------------

def _drain_one_parked_job(ckpt_dir, n_iter=3):
    s = Scheduler(n_devices=1)
    jid = s.submit(_job(n_iter=n_iter))
    s.run(max_quanta=1)
    s.drain(ckpt_dir)
    return jid


def test_restore_truncated_no_commit_fails_loudly(tmp_path):
    """spec.json present but no committed step (COMMIT removed): restore
    must raise, never silently drop the job the operator thinks is
    parked safely."""
    ckpt = str(tmp_path / "snap")
    jid = _drain_one_parked_job(ckpt)
    job_dir = os.path.join(ckpt, "jobs", jid)
    for d in os.listdir(job_dir):
        commit = os.path.join(job_dir, d, "COMMIT")
        if os.path.exists(commit):
            os.remove(commit)
    fresh = Scheduler(n_devices=1)
    with pytest.raises(ValueError, match="truncated"):
        fresh.restore(ckpt)
    assert not fresh.records                     # two-phase: untouched


def test_restore_missing_step_dirs_fails_loudly(tmp_path):
    ckpt = str(tmp_path / "snap")
    jid = _drain_one_parked_job(ckpt)
    job_dir = os.path.join(ckpt, "jobs", jid)
    for d in os.listdir(job_dir):
        if d.startswith("step_"):
            shutil.rmtree(os.path.join(job_dir, d))
    with pytest.raises(ValueError, match="no committed step"):
        Scheduler(n_devices=1).restore(ckpt)


@pytest.mark.parametrize("status", ["cancelled", "completed", "stolen"])
def test_restore_skips_terminal_specs(tmp_path, status):
    """A snapshot whose spec records a terminal status is stale — the
    work finished or moved elsewhere; restore must not resurrect it."""
    ckpt = str(tmp_path / "snap")
    jid = _drain_one_parked_job(ckpt)
    spec_path = os.path.join(ckpt, "jobs", jid, "spec.json")
    with open(spec_path) as f:
        spec = json.load(f)
    spec["status"] = status
    with open(spec_path, "w") as f:
        json.dump(spec, f)
    assert Scheduler(n_devices=1).restore(ckpt) == 0


def test_snapshot_racing_terminal_transition_cannot_resurrect(
        tmp_path, monkeypatch):
    """A job cancelled (or stolen/completed) while the periodic snapshot
    is writing its payload outside the lock must still end up terminal
    on disk — the pre-write stale-out no-ops (no spec yet), so the
    scheduler re-checks after the write lands."""
    import repro.serve.scheduler as sched_mod
    ckpt = str(tmp_path / "snap")
    sched = Scheduler(n_devices=1, memory=_mem(100), snapshot_dir=ckpt)
    busy = sched.submit(_job(n_iter=2))
    victim = sched.submit(_job(n_iter=2))
    sched.admit()

    orig_write = sched_mod._write_job

    def racing_write(ckpt_dir, job_id, spec, tree, step):
        if job_id == victim:
            # lands in the unlocked write window, before spec.json
            # exists: the cancel's own stale-out has nothing to flip
            assert sched.cancel(victim)
        orig_write(ckpt_dir, job_id, spec, tree, step)

    monkeypatch.setattr(sched_mod, "_write_job", racing_write)
    # parked jobs only: the race under test is the victim's unlocked
    # write window, not the running job's live snapshot
    assert sched.snapshot(ckpt, include_running=False) == 1
    assert Scheduler(n_devices=1).restore(ckpt) == 0
    sched.run()
    assert sched.records[busy].status is JobStatus.COMPLETED


def test_terminal_jobs_reclaim_snapshot_payload(tmp_path):
    """Once a snapshotted job finishes, its step directories (the full
    projections payload) are deleted and only the terminal spec
    tombstone remains — a long-lived server must not leak one
    checkpoint per job ever parked."""
    ckpt = str(tmp_path / "snap")
    sched = Scheduler(n_devices=1, snapshot_dir=ckpt)
    jid = sched.submit(_job(n_iter=3))
    sched.run(max_quanta=1)
    sched.drain(ckpt)
    job_dir = os.path.join(ckpt, "jobs", jid)
    assert any(d.startswith("step_") for d in os.listdir(job_dir))
    sched.run()                      # re-admits from its queue, completes
    assert sched.records[jid].status is JobStatus.COMPLETED
    with open(os.path.join(job_dir, "spec.json")) as f:
        assert json.load(f)["status"] == "completed"
    assert not any(d.startswith("step_") for d in os.listdir(job_dir))
    assert Scheduler(n_devices=1).restore(ckpt) == 0


def test_restore_lazy_job_without_ref_raises_then_succeeds(tmp_path):
    ckpt = str(tmp_path / "snap")
    s = Scheduler(n_devices=1)
    jid = s.submit(ReconJob("cgls", GEO, ANGLES, lambda: PROJ, n_iter=3))
    s.run(max_quanta=1)
    s.drain(ckpt)
    with pytest.raises(ValueError, match="lazy"):
        Scheduler(n_devices=1).restore(ckpt)
    s2 = Scheduler(n_devices=1)
    assert s2.restore(ckpt, data_refs={jid: lambda: PROJ}) == 1
    s2.run()
    np.testing.assert_array_equal(
        s2.result(jid), np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=3)))


# --------------------------------------------------------------------------
# retired-pod compaction (bounded memory for long-lived autoscaled fleets)
# --------------------------------------------------------------------------

def _run_and_retire(mps, pod_name="p1"):
    """Complete one job on each pod, then retire ``pod_name`` (idle)."""
    jids = [mps.submit(_job(n_iter=1), pod=p.name) for p in mps.pods]
    mps.run()
    mps.remove_pod(pod_name)
    return jids


def test_retired_pod_kept_without_ttl():
    mps = MultiPodScheduler(_pods(2), steal=False)
    jids = _run_and_retire(mps)
    assert [p.name for p in mps.retired_pods] == ["p1"]
    mps.compact_retired()                  # no TTL: never folds
    assert mps.retired_pods and not mps.retired_summaries
    for jid in jids:                       # results stay answerable
        assert mps.result(jid) is not None


def test_retired_pod_compacts_after_ttl():
    mps = MultiPodScheduler(_pods(2), steal=False,
                            retired_pod_ttl_seconds=0.05)
    jids = _run_and_retire(mps)
    completed_before = mps.metrics().completed
    # inside the TTL: still a full Pod, result answerable
    assert mps.compact_retired() == 0
    on_retired = [j for j in jids if mps.owner(j).name == "p1"]
    assert on_retired and mps.result(on_retired[0]) is not None
    time.sleep(0.06)
    assert mps.compact_retired() == 1      # TTL expired: folded
    assert not mps.retired_pods
    [summ] = mps.retired_summaries
    assert summ.name == "p1"
    assert summ.job_statuses[on_retired[0]] == "completed"
    # counters, busy clocks and the per-pod summary survive compaction
    assert mps.metrics().completed == completed_before
    s = mps.summary()
    assert s["retired_pods"]["p1"]["compacted"] is True
    assert s["retired_pods"]["p1"]["completed"] == len(on_retired)
    assert s["completed"] == completed_before
    # the result arrays do not: owner()/result() fail loudly, naming it
    with pytest.raises(KeyError, match="compacted"):
        mps.owner(on_retired[0])
    with pytest.raises(KeyError, match="compacted"):
        mps.result(on_retired[0])
    with pytest.raises(KeyError, match="unknown job"):
        mps.owner("never-submitted")


def test_compaction_triggered_by_reporting_and_guards_names():
    mps = MultiPodScheduler(_pods(2), steal=False,
                            retired_pod_ttl_seconds=0.0)
    _run_and_retire(mps)
    # metrics()/summary() run the opportunistic compaction pass
    mps.metrics()
    assert not mps.retired_pods and len(mps.retired_summaries) == 1
    # a compacted name stays reserved (records merged into fleet history)
    with pytest.raises(ValueError, match="already used"):
        mps.add_pod(Pod(PodSpec("p1", n_devices=1, memory=_mem(220))))


# --------------------------------------------------------------------------
# steal_pass pins its victim/thief pairing for the whole pass
# --------------------------------------------------------------------------

def test_steal_pass_pins_pairing_and_never_bounces_jobs_back(tmp_path):
    """Regression: steal_pass used to re-rank victim/thief after every
    move, so a steal that inverted the load ordering by a hair made the
    *former thief* the new victim — and its own queued job bounced
    straight back toward the pod the pass was unloading (under unit
    skew, systematically toward the warm pod).  The pairing is now
    pinned per pass: with pod a holding two 4-iteration jobs and pod b
    one 1-iteration job (equal unit costs), moving one job a->b inverts
    the ranking (a=4, b=5), and the old code would then move b's own
    tiny job b->a."""
    a, b = _pods(2, kib=800)
    a_jobs = [a.scheduler.submit(_job(n_iter=4)) for _ in range(2)]
    tiny = b.scheduler.submit(_job(n_iter=1))
    # identical observed unit costs on both pods: the imbalance is pure
    # queue depth, so the modeled loads are exact integers (a=8, b=1)
    a.scheduler._step_ema = 1.0
    b.scheduler._step_ema = 1.0
    moved = steal_pass([a, b], str(tmp_path / "xfer"))
    assert moved, "the imbalanced pass must move at least one job"
    assert set(moved) <= set(a_jobs), \
        f"pass moved non-victim jobs: {moved}"
    assert tiny in b.scheduler.records, \
        "thief's own queued job bounced back to the victim mid-pass"
    for pod in (a, b):
        pod.scheduler.run()
    want = np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=4))
    for jid in a_jobs:
        owner = a if jid in a.scheduler.records else b
        np.testing.assert_array_equal(owner.scheduler.result(jid), want)
