"""Bandwidth-EMA pricing: ``Scheduler.modeled_transfer_seconds`` prices
one outer iteration's host<->device staging off the CommSchedule at the
*measured* bandwidth EMA, degrades to 0.0 whenever it cannot know better
(in-core job, no bandwidth observed yet), and is folded into the backlog
signal that fleet routing / stealing / autoscaling balance against."""

import numpy as np
import pytest

from repro.core import phantoms
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.plan import plan as plan_execution
from repro.core.splitting import MemoryModel
from repro.serve import ReconJob, Scheduler

GEO = ConeGeometry.nice(16)
ANGLES = circular_angles(12)
PROJ = phantoms.sphere_projection_analytic(GEO, ANGLES)
KIB = 1024
BW = 64 * KIB * 1024.0          # 64 MiB/s, bytes per second


def _mem(kib=220):
    return MemoryModel(device_bytes=kib * KIB, usable_fraction=1.0)


def _job(n_iter=4, **kw):
    return ReconJob("cgls", GEO, ANGLES, PROJ, n_iter=n_iter, **kw)


def test_transfer_seconds_prices_schedule_at_measured_bandwidth():
    """For a streamed job the price is exactly the execution plan's
    CommSchedule bytes over the observed bandwidth — the same IR the
    executors stage from, so pricing and execution cannot drift."""
    sched = Scheduler(n_devices=1, memory=_mem())
    sched._bandwidth_ema = BW
    job = _job(mode="stream")
    expected = plan_execution(GEO, len(ANGLES), 1,
                              _mem()).comm.transfer_seconds(BW)
    assert expected > 0.0
    assert sched.modeled_transfer_seconds(job) == pytest.approx(expected)
    # twice the bandwidth, half the price
    sched._bandwidth_ema = 2 * BW
    assert sched.modeled_transfer_seconds(job) == pytest.approx(expected / 2)


def test_transfer_seconds_degrades_to_zero():
    sched = Scheduler(n_devices=1, memory=_mem())
    assert sched.bandwidth_ema is None            # nothing observed yet
    assert sched.modeled_transfer_seconds(_job(mode="stream")) == 0.0
    sched._bandwidth_ema = BW
    # in-core job: operands stay resident, no staging to price
    assert not sched.job_footprint(_job()).streams
    assert sched.modeled_transfer_seconds(_job()) == 0.0


def test_backlog_folds_transfer_price_per_remaining_iteration():
    """The load signal owes `remaining * transfer` extra seconds for a
    queued streamed job once a bandwidth has been observed — a pod on a
    slow link looks (correctly) more loaded than one on a fast link."""
    sched = Scheduler(n_devices=1, memory=_mem())
    job = _job(n_iter=4, mode="stream")
    sched.submit(job)                             # queued, never admitted
    base = sched.modeled_backlog_seconds(unit=1.0, init=0.0)
    sched._bandwidth_ema = BW
    per_iter = sched.modeled_transfer_seconds(job)
    assert per_iter > 0.0
    priced = sched.modeled_backlog_seconds(unit=1.0, init=0.0)
    assert priced == pytest.approx(base + 4 * per_iter)
    # faster link -> smaller owed backlog, same ordering as the price
    sched._bandwidth_ema = 4 * BW
    assert sched.modeled_backlog_seconds(unit=1.0, init=0.0) < priced
    np.testing.assert_allclose(
        sched.modeled_backlog_seconds(unit=1.0, init=0.0),
        base + 4 * per_iter / 4)
