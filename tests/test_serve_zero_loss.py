"""Zero-loss serving: copy-on-checkpoint snapshots of *running* jobs
(a kill -9 between snapshots loses zero committed iterations), single-job
preemption (``park_job``), live migration (``migrate_once`` + the
``steal_pass`` extreme-imbalance escalation), predictive autoscale
(init-EMA lead time), real-device fleet restore onto a pod mesh, and
``recover_transfers`` — the on-restore adoption of jobs stranded mid
hand-off in the transfer directory."""

import functools
import json
import os
import shutil

import numpy as np
import pytest

from repro import obs
from repro.core import phantoms
from repro.core.algorithms import cgls
from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.splitting import MemoryModel
from repro.serve import (Autoscaler, AutoscalePolicy, JobStatus,
                         MultiPodScheduler, Pod, PodSpec, ReconJob,
                         Scheduler, StealPolicy, migrate_once)

GEO = ConeGeometry.nice(16)
ANGLES = circular_angles(12)
PROJ = phantoms.sphere_projection_analytic(GEO, ANGLES)
KIB = 1024


def _mem(kib=220):
    return MemoryModel(device_bytes=kib * KIB, usable_fraction=1.0)


def _job(n_iter=4):
    return ReconJob("cgls", GEO, ANGLES, PROJ, n_iter=n_iter)


@functools.lru_cache(maxsize=None)
def _ref(n_iter):
    """Uninterrupted single-shot reference — every resumed/migrated/
    recovered run below must match it bit-for-bit."""
    return np.asarray(cgls(PROJ, GEO, ANGLES, n_iter=n_iter))


@pytest.fixture
def tracer():
    t = obs.get_tracer()
    t.clear()
    t.enable()
    yield t
    t.disable()
    t.clear()


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# --------------------------------------------------------------------------
# copy-on-checkpoint: running jobs in snapshots
# --------------------------------------------------------------------------

def test_running_job_snapshot_loses_zero_iterations(tmp_path):
    """The exact-iteration zero-loss contract: snapshot a RUNNING job at
    iteration k without parking it, let it keep running, kill -9
    (discard the live scheduler), restore — the job resumes at exactly
    k (nothing lost, nothing replayed) and finishes bit-identically."""
    snap = str(tmp_path / "snap")
    sched = Scheduler(n_devices=1, memory=_mem())
    jid = sched.submit(_job(n_iter=5))
    sched.step_quantum()
    sched.step_quantum()
    k = sched.records[jid].iterations_done
    assert sched.records[jid].status is JobStatus.RUNNING
    assert k >= 1
    assert sched.snapshot(snap) == 1          # no parking involved
    assert sched.records[jid].status is JobStatus.RUNNING
    sched.step_quantum()                      # progress past the snapshot
    assert sched.records[jid].iterations_done > k

    fresh = Scheduler(n_devices=1, memory=_mem())
    assert fresh.restore(snap) == 1
    assert fresh.records[jid].iterations_done == k
    fresh.run()
    np.testing.assert_array_equal(fresh.result(jid), _ref(5))


def test_live_snapshot_emits_event_and_dedups(tmp_path, tracer):
    """A running job's persisted boundary shows up as a ``live-snapshot``
    fleet event carrying the committed iteration; re-snapshotting with
    no new progress writes nothing (fingerprint dedup)."""
    snap = str(tmp_path / "snap")
    sched = Scheduler(n_devices=1, memory=_mem(), name="solo")
    jid = sched.submit(_job(n_iter=5))
    sched.step_quantum()
    k = sched.records[jid].iterations_done
    assert sched.snapshot(snap) == 1
    (ev,) = obs.fleet_event_log(kind="live-snapshot")
    assert ev.attrs["job"] == jid
    assert ev.attrs["pod"] == "solo"
    assert ev.attrs["it"] == k
    # unchanged state -> nothing rewritten, no second event
    assert sched.snapshot(snap) == 0
    assert len(obs.fleet_event_log(kind="live-snapshot")) == 1
    sched.step_quantum()
    assert sched.snapshot(snap) == 1          # fresh boundary, fresh write


# --------------------------------------------------------------------------
# park_job: single-job preemption (the migration building block)
# --------------------------------------------------------------------------

def test_park_job_preempts_one_running_job_only(tmp_path):
    sched = Scheduler(n_devices=2, memory=_mem())
    a = sched.submit(_job(n_iter=4))
    b = sched.submit(_job(n_iter=4))
    sched.step_quantum()
    assert {a, b} <= set(sched.running)
    assert sched.park_job(a)
    assert sched.records[a].status is JobStatus.PREEMPTED
    assert a not in sched.running
    assert b in sched.running                 # untouched
    assert not sched.park_job("nonexistent")
    sched.run()
    for jid in (a, b):
        np.testing.assert_array_equal(sched.result(jid), _ref(4))


# --------------------------------------------------------------------------
# live migration
# --------------------------------------------------------------------------

def test_migrate_once_moves_running_job_bit_identically(tmp_path, tracer):
    transfer = str(tmp_path / "transfer")
    vict = Pod(PodSpec("v", n_devices=1, memory=_mem()))
    thief = Pod(PodSpec("t", n_devices=1, memory=_mem()))
    mps = MultiPodScheduler([vict, thief], steal=False,
                            transfer_dir=transfer)
    jobs = [mps.submit(_job(n_iter=4), pod="v") for _ in range(2)]
    vict.scheduler.step_quantum()
    running = set(vict.scheduler.running)
    assert running                            # something to migrate

    moved = migrate_once(vict, thief, transfer)
    assert moved in running                   # a RUNNING job, not a parked one
    assert moved in thief.scheduler.records
    assert vict.scheduler.records.get(moved) is None \
        or vict.scheduler.records[moved].status is JobStatus.STOLEN
    (ev,) = obs.fleet_event_log(kind="migrate")
    assert ev.attrs["job"] == moved
    assert (ev.attrs["src"], ev.attrs["dst"]) == ("v", "t")
    mps.run()
    for jid in jobs:
        np.testing.assert_array_equal(mps.result(jid), _ref(4))


def test_migrate_once_skips_when_move_has_no_benefit(tmp_path, tracer):
    """Anti-ping-pong: when the thief is at least as loaded as the
    victim, the move would just invert the imbalance — nothing moves."""
    transfer = str(tmp_path / "transfer")
    vict = Pod(PodSpec("v", n_devices=1, memory=_mem()))
    thief = Pod(PodSpec("t", n_devices=1, memory=_mem()))
    mps = MultiPodScheduler([vict, thief], steal=False,
                            transfer_dir=transfer)
    jid = mps.submit(_job(n_iter=4), pod="v")
    for _ in range(3):                        # thief is the busy one
        mps.submit(_job(n_iter=4), pod="t")
    vict.scheduler.step_quantum()
    assert migrate_once(vict, thief, transfer) is None
    assert jid in vict.scheduler.records
    assert not obs.fleet_event_log(kind="migrate")


def test_steal_pass_escalates_to_migration_on_extreme_imbalance(tmp_path):
    """``steal_pass`` only migrates when (a) the policy opts in and
    (b) nothing parked could be stolen — a victim whose whole backlog is
    RUNNING sheds load only through the live-migration escape hatch."""
    def fleet(policy, sub):
        transfer = str(tmp_path / f"transfer-{sub}")
        v = Pod(PodSpec("v", n_devices=1, memory=_mem()))
        t = Pod(PodSpec("t", n_devices=1, memory=_mem()))
        mps = MultiPodScheduler([v, t], steal=True, steal_policy=policy,
                                transfer_dir=transfer)
        jid = mps.submit(_job(n_iter=4), pod="v")
        v.scheduler.step_quantum()            # running; queue empty
        assert not v.scheduler.steal_candidates()
        # pin the fleet unit scale: the measured EMAs of a 16^3 toy job
        # are microseconds of step against a real (re)init, which would
        # correctly price the migration as not worth it
        v.scheduler._step_ema = 1.0
        v.scheduler._init_ema = 0.0
        return mps, v, t, jid

    # default policy: running work is never touched
    mps0, v0, t0, j0 = fleet(StealPolicy(), "off")
    assert mps0.steal_pass() == []
    assert j0 in v0.scheduler.records

    # opted in: the running job moves live and finishes bit-identically
    pol = StealPolicy(migrate_min_imbalance_seconds=1.0)
    mps1, v1, t1, j1 = fleet(pol, "on")
    assert mps1.steal_pass() == [j1]
    assert j1 in t1.scheduler.records
    mps1.run()
    np.testing.assert_array_equal(mps1.result(j1), _ref(4))


# --------------------------------------------------------------------------
# predictive scale-up
# --------------------------------------------------------------------------

def _asc_policy(**kw):
    kw.setdefault("scale_up_backlog_seconds", 0.5)
    kw.setdefault("scale_down_backlog_seconds", 0.01)
    kw.setdefault("up_window_seconds", 0.0)
    kw.setdefault("down_window_seconds", 1e9)
    kw.setdefault("cooldown_seconds", 0.0)
    kw.setdefault("max_pods", 2)
    return AutoscalePolicy(**kw)


def test_predictive_scale_up_fires_on_projected_crossing(tmp_path):
    """With ``predictive_scale_up`` on, a load still *below* the high
    watermark triggers growth when its observed slope projects it across
    within the fleet's init-EMA lead time — the pod is live by the time
    the band is actually crossed."""
    seed = Pod(PodSpec("seed", n_devices=1, memory=_mem()))
    mps = MultiPodScheduler([seed], steal=False,
                            transfer_dir=str(tmp_path / "transfer"))
    seed.scheduler._init_ema = 5.0            # observed: init takes ~5s
    load = {"v": 0.1}
    clock = FakeClock()
    asc = Autoscaler(mps, [PodSpec("burst", n_devices=1, memory=_mem())],
                     _asc_policy(predictive_scale_up=True), clock=clock,
                     load_fn=lambda pods: load["v"])
    assert asc.step() is None                 # first sample: no slope yet
    clock.t, load["v"] = 1.0, 0.2             # slope 0.1/s x 5s lead = +0.5
    ev = asc.step()
    assert ev is not None and ev.direction == "up" and ev.predicted
    assert len(mps.pods) == 2
    assert [e.predicted for e in asc.events] == [True]


def test_predictive_scale_up_is_off_by_default(tmp_path):
    seed = Pod(PodSpec("seed", n_devices=1, memory=_mem()))
    mps = MultiPodScheduler([seed], steal=False,
                            transfer_dir=str(tmp_path / "transfer"))
    seed.scheduler._init_ema = 5.0
    load = {"v": 0.1}
    clock = FakeClock()
    asc = Autoscaler(mps, [PodSpec("burst", n_devices=1, memory=_mem())],
                     _asc_policy(), clock=clock,
                     load_fn=lambda pods: load["v"])
    assert AutoscalePolicy().predictive_scale_up is False
    assert asc.step() is None
    clock.t, load["v"] = 1.0, 0.2             # same ramp, below watermark
    assert asc.step() is None                 # reactive-only: no event
    assert len(mps.pods) == 1


# --------------------------------------------------------------------------
# real-device restore: budgets in the manifest, pins from the mesh
# --------------------------------------------------------------------------

def test_restore_fleet_onto_mesh_pins_real_devices(tmp_path):
    from repro.launch.mesh import make_pod_mesh, pod_device_groups

    root = str(tmp_path / "fleet")
    mps = MultiPodScheduler(
        [Pod(PodSpec("a", n_devices=4, memory=_mem())),
         Pod(PodSpec("b", n_devices=4, memory=_mem()))],
        steal=False, snapshot_root=root)
    jobs = [mps.submit(_job(n_iter=3)) for _ in range(2)]
    assert mps.snapshot_fleet() == len(jobs)

    mesh = make_pod_mesh(2)
    assert mesh.axis_names == ("pod", "data", "model")
    mps2 = MultiPodScheduler.restore_fleet(root, mesh=mesh)
    groups = pod_device_groups(mesh)
    for pod, group in zip(mps2.pods, groups):
        assert [s.jax_device for s in pod.pool.slots] == list(group)
    mps2.run()
    for jid in jobs:
        np.testing.assert_array_equal(mps2.result(jid), _ref(3))


def test_restore_fleet_mesh_mismatch_raises(tmp_path):
    from repro.launch.mesh import make_pod_mesh

    root = str(tmp_path / "fleet")
    mps = MultiPodScheduler(
        [Pod(PodSpec("a", n_devices=1, memory=_mem())),
         Pod(PodSpec("b", n_devices=1, memory=_mem()))],
        steal=False, snapshot_root=root)
    mps.submit(_job(n_iter=2))
    mps.snapshot_fleet()
    # 2 mesh pods x 4 devices vs 2 manifest pods x 1 device
    with pytest.raises(ValueError, match="a"):
        MultiPodScheduler.restore_fleet(root, mesh=make_pod_mesh(2))
    # 4 mesh pods vs 2 manifest pods
    with pytest.raises(ValueError, match="pods"):
        MultiPodScheduler.restore_fleet(root, mesh=make_pod_mesh(4))
    # and the mesh builder itself rejects a non-dividing pod count
    with pytest.raises(ValueError, match="split"):
        make_pod_mesh(3)


# --------------------------------------------------------------------------
# recover_transfers: jobs stranded mid hand-off
# --------------------------------------------------------------------------

def _fleet(tmp_path):
    # 100 KiB: one job fits per device, so job 1 stays queued (parked)
    # on the victim — exportable without a preemption
    root = str(tmp_path / "fleet")
    transfer = str(tmp_path / "transfer")
    mps = MultiPodScheduler(
        [Pod(PodSpec("v", n_devices=1, memory=_mem(100))),
         Pod(PodSpec("t", n_devices=1, memory=_mem(100)))],
        steal=False, transfer_dir=transfer, snapshot_root=root)
    jobs = [mps.submit(_job(n_iter=4), pod="v") for _ in range(2)]
    vict = next(p for p in mps.pods if p.name == "v")
    thief = next(p for p in mps.pods if p.name == "t")
    vict.scheduler.step_quantum()
    return mps, transfer, vict, thief, jobs


def test_recover_transfers_adopts_orphan_skips_torn(tmp_path):
    """A clean export whose import never happened is a live orphan —
    recovery re-adopts it exactly once; a torn export (no spec.json yet)
    still belongs to the victim's own snapshot and is left alone."""
    mps, transfer, vict, thief, jobs = _fleet(tmp_path)
    assert vict.scheduler.export_job(jobs[1], transfer)
    torn = os.path.join(transfer, "jobs", "zz-torn")
    os.makedirs(torn)                         # crashed before spec.json

    res = mps.recover_transfers()
    assert res == {"imported": [jobs[1]], "dropped": []}
    assert os.path.isdir(torn)                # untouched
    assert not os.path.isdir(os.path.join(transfer, "jobs", jobs[1]))
    owners = [p.name for p in mps.pods if jobs[1] in p.scheduler.records]
    assert len(owners) == 1
    assert jobs[1] in mps.recovered_jobs
    mps.run()
    for jid in jobs:
        np.testing.assert_array_equal(mps.result(jid), _ref(4))


def test_recover_transfers_drops_terminal_and_duplicate(tmp_path):
    """A half-consumed import (terminal spec) and a copy of a job some
    pod already knows are both tombstones — dropped, never resurrected."""
    mps, transfer, vict, thief, jobs = _fleet(tmp_path)
    # terminal: a transfer copy whose consumption crashed mid-way
    dead = os.path.join(transfer, "jobs", "zz-dead")
    os.makedirs(dead)
    with open(os.path.join(dead, "spec.json"), "w") as f:
        json.dump({"status": "stolen"}, f)
    # duplicate: preserve the transfer copy across a completed hand-off
    assert vict.scheduler.export_job(jobs[1], transfer)
    src = os.path.join(transfer, "jobs", jobs[1])
    keep = str(tmp_path / "dup-copy")
    shutil.copytree(src, keep)
    assert thief.scheduler.import_job(transfer, jobs[1]) == jobs[1]
    shutil.copytree(keep, src)                # the stale duplicate returns

    res = mps.recover_transfers()
    assert res["imported"] == []
    assert sorted(res["dropped"]) == sorted([jobs[1], "zz-dead"])
    assert not os.path.isdir(dead)
    assert not os.path.isdir(src)             # consumed, not re-imported
    owners = [p.name for p in mps.pods if jobs[1] in p.scheduler.records]
    assert owners == ["t"]
    mps.run()
    for jid in jobs:
        np.testing.assert_array_equal(mps.result(jid), _ref(4))


def test_recover_transfers_stranded_job_raises(tmp_path, monkeypatch):
    """Zero-loss means loud: an orphan NO live pod can adopt must raise,
    not silently vanish from the fleet."""
    mps, transfer, vict, thief, jobs = _fleet(tmp_path)
    assert vict.scheduler.export_job(jobs[1], transfer)

    def refuse(self, *a, **k):
        raise RuntimeError("no capacity")

    monkeypatch.setattr(Scheduler, "import_job", refuse)
    with pytest.raises(RuntimeError, match="stranded"):
        mps.recover_transfers()
    # the transfer copy survives for the next recovery attempt
    assert os.path.isdir(os.path.join(transfer, "jobs", jobs[1]))
