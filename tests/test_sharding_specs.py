"""Sharding-rule plumbing: divisibility fallbacks, param/cache sharding
trees, step builders on a small mesh."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import reduced
from repro.distributed.sharding import (batch_sharding, make_lm_rules,
                                        param_shardings)
from repro.launch.steps import (abstract_params, build_serve_step,
                                build_train_step, cache_shardings)
from repro.models.lm import make_model


def test_rules_divisibility_fallback(host_mesh):
    rules = make_lm_rules(host_mesh)            # model axis = 2
    # divisible: kept
    assert rules.spec(("batch", "mlp"), (8, 16)) == P("data", "model")
    # not divisible: dropped to replicated
    assert rules.spec(("batch", "mlp"), (3, 7)) == P(None, None)
    # length-1 decode axis dropped
    assert rules.spec(("batch", None, "vocab"), (1, 1, 10)) == \
        P(None, None, "model")


def test_param_shardings_cover_tree(host_mesh):
    cfg = reduced("deepseek-moe-16b")
    rules = make_lm_rules(host_mesh)
    model = make_model(cfg, rules)
    p_shape = abstract_params(cfg)
    shards = param_shardings(model, rules, p_shape)
    n_leaves = len(jax.tree.leaves(p_shape))
    n_shards = len(jax.tree.leaves(
        shards, is_leaf=lambda x: x is None or hasattr(x, "spec")))
    assert n_leaves == n_shards
    # expert weights sharded over model on the expert axis
    spec = shards["stack"]["b0"]["moe"]["w_gate"].spec
    assert spec[1] == "model"


def test_batch_sharding(host_mesh):
    rules = make_lm_rules(host_mesh)
    specs = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
             "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    sh = batch_sharding(rules, specs)
    assert sh["tokens"].spec == P("data", None)
    assert sh["pos"].spec == P()


def test_cache_shardings_layout(host_mesh):
    cfg = reduced("gemma2-9b")
    rules = make_lm_rules(host_mesh)
    model = make_model(cfg)
    caches = jax.eval_shape(lambda: model.init_cache(8, 32))
    sh = cache_shardings(rules, caches)
    # stacked kv cache: layers axis replicated, batch on data,
    # kv-heads(2 % 2 == 0) on model
    spec = sh["stack"]["b0"]["k"].spec
    assert spec[0] is None and spec[1] == "data" and spec[2] == "model"
    # pos arrays replicated
    assert sh["stack"]["b0"]["pos"].spec == P()


def test_train_step_runs_on_host_mesh(host_mesh):
    """End-to-end: the builder's jitted step EXECUTES on a real (4,2) CPU
    mesh for a reduced arch, producing finite loss."""
    import repro.configs as C
    cfg = reduced("stablelm-1.6b")
    # shrink the cell to smoke scale
    C.SHAPES["train_smoke"] = (32, 8)
    try:
        built = build_train_step(cfg, host_mesh, "train_smoke", zero1=True)
        with host_mesh:
            model = make_model(cfg, make_lm_rules(host_mesh))
            params = jax.jit(
                model.init,
                out_shardings=built.in_shardings[0])(jax.random.PRNGKey(0))
            from repro.optim import adamw_init
            opt = jax.jit(adamw_init,
                          out_shardings=built.in_shardings[1])(params)
            batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                     "labels": jnp.zeros((8, 32), jnp.int32)}
            new_p, new_o, metrics = built.jitted(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert int(new_o["step"]) == 1
    finally:
        del C.SHAPES["train_smoke"]


def test_serve_step_runs_on_host_mesh(host_mesh):
    import repro.configs as C
    cfg = reduced("stablelm-1.6b")
    C.SHAPES["decode_smoke"] = (64, 8)
    try:
        built = build_serve_step(cfg, host_mesh, "decode_smoke",
                                 donate=False)
        with host_mesh:
            model = make_model(cfg, make_lm_rules(host_mesh))
            params = jax.jit(
                model.init,
                out_shardings=built.in_shardings[0])(jax.random.PRNGKey(0))
            caches = jax.jit(
                lambda: model.init_cache(8, 64),
                out_shardings=built.in_shardings[3])()
            logits, caches = built.jitted(
                params, jnp.zeros((8, 1), jnp.int32),
                jnp.asarray(0, jnp.int32), caches)
        assert logits.shape == (8, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
    finally:
        del C.SHAPES["decode_smoke"]
