"""Paper SS2.1/SS2.2: the splitting planner's invariants and the exactness
of slab-split operators (hypothesis property tests)."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.geometry import ConeGeometry, circular_angles, \
    dominant_axis_mask
from repro.core.projector import backproject_voxel, forward_project_joseph
from repro.core.splitting import (MemoryModel, even_splits, paper_size_limits,
                                  plan_backward, plan_forward)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 500), st.integers(1, 20))
def test_even_splits_properties(n, k):
    s = even_splits(n, k)
    assert len(s) == k
    assert s[0][0] == 0 and s[-1][1] == n
    sizes = [e - b for b, e in s]
    assert sum(sizes) == n
    assert max(sizes) - min(sizes) <= 1            # maximally even
    for (b1, e1), (b2, e2) in zip(s, s[1:]):
        assert e1 == b2                            # contiguous


@settings(max_examples=25, deadline=None)
@given(st.integers(64, 512), st.integers(16, 256), st.integers(1, 4),
       st.integers(20, 28))
def test_forward_plan_fits_budget(n, n_angles, n_dev, log2_mem):
    geo = ConeGeometry.nice(n)
    mem = MemoryModel(device_bytes=2 ** log2_mem, usable_fraction=1.0)
    try:
        plan = plan_forward(geo, n_angles, n_dev, mem)
    except MemoryError:
        return                                     # buffers alone too big
    slab_planes = max(e - b for b, e in plan.slab_ranges)
    used = (slab_planes * n * n * 4
            + (3 if plan.n_slabs > 1 else 2)
            * plan.angle_chunk * n * n * 4)
    assert used <= mem.usable
    # angle ranges tile all angles
    assert plan.angle_ranges[0][0] == 0
    assert plan.angle_ranges[-1][1] == n_angles


@settings(max_examples=25, deadline=None)
@given(st.integers(64, 512), st.integers(16, 256), st.integers(1, 4),
       st.integers(20, 28))
def test_backward_plan_fits_budget(n, n_angles, n_dev, log2_mem):
    geo = ConeGeometry.nice(n)
    mem = MemoryModel(device_bytes=2 ** log2_mem, usable_fraction=1.0)
    try:
        plan = plan_backward(geo, n_angles, n_dev, mem)
    except MemoryError:
        return
    slab_planes = max(e - b for b, e in plan.slab_ranges)
    used = slab_planes * n * n * 4 + 2 * plan.angle_chunk * n * n * 4
    assert used <= mem.usable
    assert plan.slab_ranges[0][0] == 0
    assert plan.slab_ranges[-1][1] == n


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([1, 2, 4, 8]), st.integers(0, 100))
def test_fp_slab_split_exact(n_slabs, seed):
    """Sum of per-slab partial FPs == monolithic FP (paper's key claim)."""
    geo = ConeGeometry.nice(32)
    angles = circular_angles(6)
    ax = jnp.asarray(angles[np.nonzero(dominant_axis_mask(angles))[0]])
    vol = jax.random.normal(jax.random.PRNGKey(seed), geo.n_voxel)
    full = forward_project_joseph(vol, geo, ax)
    planes = 32 // n_slabs
    part = sum(
        forward_project_joseph(vol[z0:z0 + planes], geo, ax, z0=z0)
        for z0 in range(0, 32, planes))
    np.testing.assert_allclose(part, full, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([1, 2, 4]), st.integers(0, 100))
def test_fp_marching_split_exact(n_splits, seed):
    """Splitting along the marching (x) axis is exact too."""
    geo = ConeGeometry.nice(32)
    angles = circular_angles(6)
    ax = jnp.asarray(angles[np.nonzero(dominant_axis_mask(angles))[0]])
    vol = jax.random.normal(jax.random.PRNGKey(seed), geo.n_voxel)
    full = forward_project_joseph(vol, geo, ax)
    w = 32 // n_splits
    part = sum(
        forward_project_joseph(vol[:, :, p0:p0 + w], geo, ax,
                               x_planes=(p0, p0 + w))
        for p0 in range(0, 32, w))
    np.testing.assert_allclose(part, full, rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([1, 2, 4, 8]), st.integers(0, 100))
def test_bp_slab_split_exact(n_slabs, seed):
    """Stacking per-slab BPs == monolithic BP (paper Alg 2)."""
    geo = ConeGeometry.nice(32)
    angles = jnp.asarray(circular_angles(6))
    proj = jax.random.normal(jax.random.PRNGKey(seed),
                             (6,) + geo.n_detector)
    full = backproject_voxel(proj, geo, angles)
    planes = 32 // n_slabs
    parts = [backproject_voxel(proj, geo, angles, z_start=z0,
                               z_planes=planes)
             for z0 in range(0, 32, planes)]
    np.testing.assert_allclose(jnp.concatenate(parts, 0), full,
                               rtol=1e-4, atol=1e-4)


def test_paper_size_limits():
    """Paper SS4 claims N~17000 (FP) / N~8500 (BP) on an 11 GiB device.
    With the paper's kernel chunk sizes (N_angles 9 / 32) the planner
    gives the same order (9216 / 6144); the paper's exact buffer
    accounting is approximate, so the property tested is the order of
    magnitude and the FP > BP ordering."""
    lims = paper_size_limits(angle_chunk_fp=9, angle_chunk_bp=32)
    assert 8_000 <= lims["forward"] <= 22_000
    assert 5_000 <= lims["backward"] <= 12_000
    assert lims["forward"] > lims["backward"]
