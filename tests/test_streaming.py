"""Out-of-core streaming executors == monolithic operators (paper Fig 3/5),
under forced memory budgets that require multiple slabs."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.geometry import ConeGeometry, circular_angles
from repro.core.projector import backproject_voxel, forward_project
from repro.core.splitting import MemoryModel, plan_backward, plan_forward
from repro.core.streaming import Timeline, stream_backward, stream_forward


GEO = ConeGeometry.nice(32)
ANGLES = circular_angles(12)


def _tiny_memory():
    # forces several slabs for a 32^3 volume: proj buffers + few planes
    return MemoryModel(device_bytes=80 * 1024, usable_fraction=1.0)


def test_stream_forward_matches_plain():
    vol = np.asarray(jax.random.normal(jax.random.PRNGKey(0), GEO.n_voxel))
    plan = plan_forward(GEO, len(ANGLES), 1, _tiny_memory(), angle_chunk=4)
    assert plan.n_slabs > 1, "budget should force splitting"
    got = stream_forward(vol, GEO, ANGLES, plan)
    want = np.asarray(forward_project(jnp.asarray(vol), GEO, ANGLES))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_stream_backward_matches_plain():
    proj = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                        (len(ANGLES),) + GEO.n_detector))
    plan = plan_backward(GEO, len(ANGLES), 1, _tiny_memory(), angle_chunk=4)
    assert plan.n_slabs > 1
    got = stream_backward(proj, GEO, ANGLES, plan, weight="fdk")
    want = np.asarray(backproject_voxel(jnp.asarray(proj), GEO,
                                        jnp.asarray(ANGLES), weight="fdk"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_stream_forward_multidevice():
    n_dev = min(2, jax.local_device_count())
    vol = np.asarray(jax.random.normal(jax.random.PRNGKey(2), GEO.n_voxel))
    plan = plan_forward(GEO, len(ANGLES), n_dev, _tiny_memory(),
                        angle_chunk=4)
    got = stream_forward(vol, GEO, ANGLES, plan,
                         devices=jax.local_devices()[:n_dev])
    want = np.asarray(forward_project(jnp.asarray(vol), GEO, ANGLES))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_stream_backward_multidevice():
    n_dev = min(2, jax.local_device_count())
    proj = np.asarray(jax.random.normal(jax.random.PRNGKey(3),
                                        (len(ANGLES),) + GEO.n_detector))
    plan = plan_backward(GEO, len(ANGLES), n_dev, _tiny_memory(),
                         angle_chunk=4)
    got = stream_backward(proj, GEO, ANGLES, plan,
                          devices=jax.local_devices()[:n_dev])
    want = np.asarray(backproject_voxel(jnp.asarray(proj), GEO,
                                        jnp.asarray(ANGLES)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_stream_rejects_fewer_devices_than_planned():
    """Both streaming executors must refuse a device list shorter than the
    plan (the backward path used to wrap around silently, piling several
    devices' slab queues onto one device)."""
    vol = np.asarray(jax.random.normal(jax.random.PRNGKey(5), GEO.n_voxel))
    proj = np.asarray(jax.random.normal(jax.random.PRNGKey(6),
                                        (len(ANGLES),) + GEO.n_detector))
    one_dev = jax.local_devices()[:1]
    pf = plan_forward(GEO, len(ANGLES), 2, _tiny_memory(), angle_chunk=4)
    with pytest.raises(ValueError, match="2 devices"):
        stream_forward(vol, GEO, ANGLES, pf, devices=one_dev)
    pb = plan_backward(GEO, len(ANGLES), 2, _tiny_memory(), angle_chunk=4)
    with pytest.raises(ValueError, match="2 devices"):
        stream_backward(proj, GEO, ANGLES, pb, devices=one_dev)


def test_timeline_bins():
    vol = np.asarray(jax.random.normal(jax.random.PRNGKey(4), GEO.n_voxel))
    plan = plan_forward(GEO, len(ANGLES), 1, _tiny_memory(), angle_chunk=4)
    tl = Timeline()
    stream_forward(vol, GEO, ANGLES, plan, timeline=tl)
    fr = tl.fractions()
    assert set(fr) >= {"compute", "staging"}
    assert abs(sum(fr.values()) - 1.0) < 1e-6
    assert fr["compute"] > 0
