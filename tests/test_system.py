"""End-to-end behaviour: full reconstructions through the public drivers,
and LM training that actually learns."""

import numpy as np
import pytest


def test_recon_driver_cgls():
    from repro.launch.recon import reconstruct
    _, rel = reconstruct("cgls", n=24, n_angles=48, iters=10, mode="plain",
                         verbose=False)
    assert rel < 0.45


def test_recon_driver_streaming_out_of_core():
    """The paper's headline: reconstruct a volume bigger than the (tiny,
    simulated) device memory budget."""
    from repro.launch.recon import reconstruct
    _, rel_s = reconstruct("ossart", n=24, n_angles=32, iters=3,
                           mode="stream", device_bytes=100 * 1024,
                           verbose=False)
    _, rel_p = reconstruct("ossart", n=24, n_angles=32, iters=3,
                           mode="plain", verbose=False)
    # the paper's claim: out-of-core == in-memory quality
    assert abs(rel_s - rel_p) < 1e-3, (rel_s, rel_p)
    assert rel_s < 0.6, rel_s


@pytest.mark.slow
def test_lm_training_learns():
    """~0.4M-param LM on the synthetic pipeline: loss must drop
    substantially from its init value."""
    from repro.launch.train import train
    _, _, losses = train("stablelm-1.6b", steps=20, batch=8, seq=64,
                         verbose=False, lr=1e-3)
    first = np.mean(losses[:3])
    last = np.mean(losses[-3:])
    assert last < first - 0.5, (first, last)
