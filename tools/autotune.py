#!/usr/bin/env python
"""Pre-bake the Pallas block-size autotune table.

The kernels' measured autotuner (:mod:`repro.kernels.autotune`) times a
small candidate grid per (kind, platform, geometry shape) on first use and
memoises the winner; with ``REPRO_AUTOTUNE_CACHE=path`` the table persists
across processes.  This tool runs those measurements *ahead of time* so
production runs (``recon --autotune``) start with a warm table:

    PYTHONPATH=src REPRO_AUTOTUNE_CACHE=blocks.json \\
        python tools/autotune.py --n 64 --detector 80 96

``--smoke`` is the CI entry point: it tunes a small geometry in interpret
mode, round-trips the table through the JSON cache, and asserts the tuned
blocks never fall below the static heuristic (the autotuner's floor
guarantee).  Prints ``SMOKE OK`` on success.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile


def _geometry(n: int, detector):
    from repro.core.geometry import ConeGeometry
    nv, nu = detector
    return ConeGeometry(n_voxel=(n, n, n), n_detector=(nv, nu))


def bake(n: int, detector, planes, out: str, repeats: int) -> dict:
    """Tune every kernel kind for one geometry and save the table."""
    from repro.kernels import autotune
    geo = _geometry(n, detector)
    autotune.enable(True)
    if out:
        os.environ["REPRO_AUTOTUNE_CACHE"] = out
    results = {}
    for p in planes:
        results[f"planes={p}"] = autotune.warm(geo, planes=p,
                                               repeats=repeats)
    if out:
        autotune.save(out)
    return results


def smoke() -> int:
    """CI smoke: tune, persist, reload, and assert the floor guarantee."""
    from repro.kernels import autotune

    geo = _geometry(16, (20, 24))
    autotune.clear()
    autotune.enable(True)
    fp0 = autotune.fingerprint()

    tuned = autotune.warm(geo, planes=16)
    heur = {k: autotune.heuristic_blocks(k, geo, planes=16)
            for k in ("fp", "bp", "bp_matched")}
    for kind, cfg in tuned.items():
        for name, v in cfg.items():
            h = heur[kind].get(name, 1)
            assert v >= h, (f"{kind}.{name}: tuned {v} < heuristic {h} "
                            "(floor guarantee violated)")
    assert autotune.fingerprint() > fp0, "tuning did not bump fingerprint"

    # cache round-trip: save -> clear -> load must restore every entry
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "blocks.json")
        autotune.save(path)
        before = autotune.table()
        autotune.clear()
        assert autotune.table() == {}, "clear() left entries behind"
        n = autotune.load(path)
        assert n == len(before), f"round-trip lost entries ({n}/{len(before)})"
        assert autotune.table() == before, "round-trip changed the table"
        with open(path) as f:
            doc = json.load(f)
        assert doc.get("version") == 1 and "entries" in doc

    # a warm hit must come from the table, not re-measure
    fp1 = autotune.fingerprint()
    hit = autotune.get_blocks("fp", geo, planes=16)
    assert autotune.fingerprint() == fp1, "cache hit re-measured"
    assert hit == tuned["fp"], f"cache hit {hit} != tuned {tuned['fp']}"

    autotune.enable(None)
    autotune.clear()
    print(json.dumps({"tuned": tuned, "heuristic": heur}, indent=2,
                     sort_keys=True))
    print("SMOKE OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=64,
                    help="cubic volume side for the baked geometry")
    ap.add_argument("--detector", type=int, nargs=2, default=(80, 96),
                    metavar=("NV", "NU"), help="detector rows/cols")
    ap.add_argument("--planes", type=int, nargs="*", default=None,
                    help="slab plane counts to bake (default: full volume)")
    ap.add_argument("--out", default=os.environ.get("REPRO_AUTOTUNE_CACHE",
                                                    ""),
                    help="JSON table path (default REPRO_AUTOTUNE_CACHE)")
    ap.add_argument("--repeats", type=int, default=2,
                    help="timing repeats per candidate (median taken)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small-geometry tune + cache round-trip")
    args = ap.parse_args(argv)

    if args.smoke:
        return smoke()

    planes = args.planes or [args.n]
    results = bake(args.n, tuple(args.detector), planes, args.out,
                   args.repeats)
    print(json.dumps(results, indent=2, sort_keys=True))
    if args.out:
        print(f"table written to {args.out}")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.exit(main())
