"""Benchmark-trajectory gate: append a point, compare to the last one.

CI has produced ``--json`` bench output on every run since PR 5, but
nothing ever *kept* a number — every run compared against nothing and
the repo never had a performance trajectory.  This tool closes that
loop:

1. reads one or more bench envelopes (``benchmarks/schema.py`` format,
   as written by ``bench_serve.py`` / ``bench_operators.py`` /
   ``bench_scaling.py`` ``--json``),
2. folds them into one trajectory *point* (metric names prefixed with
   their bench name),
3. appends the point to ``BENCH_<pr>.json`` at the repo root, and
4. compares it against the previous point (or ``--baseline``) with
   noise-aware warn/fail bands: a metric must move in its *worse*
   direction by more than ``--fail-pct`` to fail the gate, and metrics
   whose values sit under ``--min-value`` are ignored entirely (on a
   CI box, a 2 ms wall time is all noise).

Exit status: 0 = no regression (or nothing to compare), 1 = at least
one metric regressed past the fail band, 2 = malformed input.

Usage::

    PYTHONPATH=src python tools/bench_track.py --pr 9 \
        bench_serve.json bench_operators.json bench_scaling.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
from benchmarks import schema   # noqa: E402

TRAJECTORY_SCHEMA = 1


def load_envelopes(paths: List[str]) -> List[Dict]:
    docs = []
    for p in paths:
        with open(p) as f:
            doc = json.load(f)
        errs = schema.validate_envelope(doc)
        if errs:
            raise ValueError(f"{p}: " + "; ".join(errs))
        docs.append(doc)
    return docs


def build_point(docs: List[Dict], pr: int) -> Dict:
    merged = schema.merge_envelopes(docs)
    return {
        "pr": pr,
        "time": time.time(),
        "smoke": merged["smoke"],
        "metrics": {m["name"]: {"value": m["value"], "units": m["units"],
                                "direction": m["direction"]}
                    for m in merged["metrics"]},
    }


def load_trajectory(path: str) -> Dict:
    if not os.path.exists(path):
        return {"schema": TRAJECTORY_SCHEMA, "points": []}
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc.get("points"), list):
        raise ValueError(f"{path}: malformed trajectory (no 'points')")
    return doc


def compare(point: Dict, baseline: Optional[Dict], warn_pct: float,
            fail_pct: float, min_value: float
            ) -> Tuple[List[str], List[str], List[str]]:
    """(failures, warnings, notes) comparing ``point`` vs ``baseline``."""
    fails: List[str] = []
    warns: List[str] = []
    notes: List[str] = []
    if baseline is None:
        notes.append("no previous point: trajectory seeded, "
                     "nothing to compare")
        return fails, warns, notes
    base = baseline.get("metrics", {})
    cur = point.get("metrics", {})
    shared = sorted(set(base) & set(cur))
    if not shared:
        notes.append("no shared metrics with the previous point")
        return fails, warns, notes
    for name in shared:
        b, c = base[name]["value"], cur[name]["value"]
        direction = cur[name].get("direction",
                                  base[name].get("direction", "lower"))
        if max(abs(b), abs(c)) < min_value:
            continue    # below the noise floor: not comparable
        if b == 0:
            continue    # no relative scale to compare on
        delta_pct = 100.0 * (c - b) / abs(b)
        worse = delta_pct > 0 if direction == "lower" else delta_pct < 0
        mag = abs(delta_pct)
        desc = (f"{name}: {b:.6g} -> {c:.6g} "
                f"({delta_pct:+.1f}%, better={direction})")
        if worse and mag > fail_pct:
            fails.append(desc)
        elif worse and mag > warn_pct:
            warns.append(desc)
        elif not worse and mag > warn_pct:
            notes.append("improved: " + desc)
    return fails, warns, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="append a bench trajectory point and gate on "
                    "regressions vs the previous one")
    ap.add_argument("inputs", nargs="+",
                    help="bench --json envelope files")
    ap.add_argument("--pr", type=int, required=True,
                    help="PR number: trajectory lands in BENCH_<pr>.json")
    ap.add_argument("--out", default="",
                    help="trajectory file (default BENCH_<pr>.json next "
                         "to this repo's root)")
    ap.add_argument("--baseline", default="",
                    help="compare against the LAST point of this "
                         "trajectory file instead of the previous point "
                         "of --out")
    ap.add_argument("--warn-pct", type=float, default=15.0,
                    help="warn band: worse by more than this %% prints "
                         "a warning (default 15)")
    ap.add_argument("--fail-pct", type=float, default=40.0,
                    help="fail band: worse by more than this %% fails "
                         "the gate (default 40; smoke benches on shared "
                         "CI runners are noisy)")
    ap.add_argument("--min-value", type=float, default=5e-3,
                    help="ignore metrics whose magnitude is below this "
                         "(noise floor; default 5e-3)")
    ap.add_argument("--dry-run", action="store_true",
                    help="compare only; do not append the point")
    args = ap.parse_args(argv)

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = args.out or os.path.join(root, f"BENCH_{args.pr}.json")

    try:
        docs = load_envelopes(args.inputs)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"bench_track: bad input: {e}", file=sys.stderr)
        return 2
    point = build_point(docs, args.pr)

    traj = load_trajectory(out)
    if args.baseline:
        base_traj = load_trajectory(args.baseline)
        baseline = (base_traj["points"][-1] if base_traj["points"]
                    else None)
    else:
        baseline = traj["points"][-1] if traj["points"] else None

    fails, warns, notes = compare(point, baseline, args.warn_pct,
                                  args.fail_pct, args.min_value)
    for n in notes:
        print(f"# {n}")
    for w in warns:
        print(f"WARN {w}")
    for f in fails:
        print(f"FAIL {f}")

    if not args.dry_run:
        traj["points"].append(point)
        tmp = out + ".tmp"
        with open(tmp, "w") as f:
            json.dump(traj, f, indent=2, sort_keys=True)
        os.replace(tmp, out)
        print(f"# trajectory point appended -> {out} "
              f"({len(traj['points'])} points, "
              f"{len(point['metrics'])} metrics)")

    if fails:
        print(f"bench_track: {len(fails)} metric(s) regressed past "
              f"{args.fail_pct:.0f}%")
        return 1
    print("bench_track: no regression"
          + (f" ({len(warns)} warning(s))" if warns else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
