#!/usr/bin/env python
"""Documentation checks (run by the CI docs job and tier-1 tests).

1. **Link check**: every intra-repo markdown link (``[text](path)`` with
   a relative target) in every tracked ``*.md`` file must resolve to an
   existing file or directory, anchors stripped.  External links
   (``http(s)://``, ``mailto:``) and pure anchors are ignored.
2. **Doctests**: the fenced examples in ``README.md``,
   ``docs/serve.md`` and ``docs/operators.md`` run under :mod:`doctest`
   (same engine as ``python -m doctest <files>``) — documentation that
   stops executing fails the build instead of rotting.

Usage::

    PYTHONPATH=src python tools/check_docs.py [--root REPO_ROOT]
"""

from __future__ import annotations

import argparse
import doctest
import pathlib
import re
import sys

# [text](target) — target up to the first closing paren / whitespace
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_DIRS = {".git", ".tmp", "__pycache__", "node_modules", ".pytest_cache"}
_EXTERNAL = ("http://", "https://", "mailto:", "#")

# files whose fenced examples must execute
DOCTEST_FILES = ("README.md", "docs/serve.md", "docs/operators.md",
                 "docs/observability.md")


def markdown_files(root: pathlib.Path):
    for md in sorted(root.rglob("*.md")):
        if not _SKIP_DIRS.intersection(md.relative_to(root).parts):
            yield md


def check_links(root: pathlib.Path) -> list:
    """All broken intra-repo links, as human-readable strings."""
    errors = []
    for md in markdown_files(root):
        for target in _LINK_RE.findall(md.read_text(encoding="utf-8")):
            if target.startswith(_EXTERNAL):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (md.parent / path).exists():
                errors.append(f"{md.relative_to(root)}: broken link "
                              f"-> {target}")
    return errors


def run_doctests(root: pathlib.Path, files=DOCTEST_FILES) -> list:
    """Run each file's ``>>>`` examples (doctest.testfile semantics);
    returns failure descriptions.  Examples within one file share a
    namespace, so later blocks can build on earlier ones."""
    errors = []
    for rel in files:
        path = root / rel
        if not path.exists():
            errors.append(f"{rel}: missing (doctest target)")
            continue
        # default flags on purpose: the CI docs job also runs the plain
        # ``python -m doctest README.md docs/serve.md`` command, and the
        # two runners must agree on what passes
        result = doctest.testfile(str(path), module_relative=False,
                                  verbose=False)
        if result.failed:
            errors.append(f"{rel}: {result.failed} of {result.attempted} "
                          f"doctest examples failed")
        elif result.attempted == 0:
            errors.append(f"{rel}: no doctest examples found (expected "
                          f"at least one fenced >>> block)")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=str(pathlib.Path(__file__).parents[1]),
                    help="repository root to scan (default: this repo)")
    ap.add_argument("--skip-doctests", action="store_true",
                    help="only check links (doctests need PYTHONPATH=src "
                         "and a working jax)")
    args = ap.parse_args()
    root = pathlib.Path(args.root).resolve()

    errors = check_links(root)
    n_md = len(list(markdown_files(root)))
    print(f"[check_docs] link check: {n_md} markdown files, "
          f"{len(errors)} broken links")
    if not args.skip_doctests:
        derr = run_doctests(root)
        print(f"[check_docs] doctests: {len(DOCTEST_FILES)} files, "
              f"{len(derr)} failures")
        errors += derr
    for e in errors:
        print(f"[check_docs] FAIL: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
