#!/usr/bin/env python
"""Validate a Chrome-trace JSON (and optionally a bench ``--json`` file).

CI runs a traced streaming reconstruction and a traced benchmark smoke
and then gates on this script: the trace must be structurally loadable
by Perfetto / chrome://tracing (the paper's Fig 3/5 timeline view) and
must actually contain the per-slab phase spans the tracing layer
promises — an instrumentation regression that silently drops the
h2d/compute/d2h spans fails here, not in a human's Perfetto tab.

Checks (Chrome trace):

* top level is ``{"traceEvents": [...]}`` with a non-empty list;
* every event has ``ph``, ``name``, ``pid``, ``tid``; duration events
  (``ph == "X"``) additionally carry numeric ``ts`` and ``dur >= 0``;
* instant events (``ph == "i"``) carry a scope ``s``;
* with ``--require-phases`` (the recon smoke): at least one complete
  span in each of the h2d / compute / d2h categories, and at least one
  span carrying a ``slab`` arg on a named device track;
* ``prefetch`` / ``reduce`` spans (the CommSchedule executors' lookahead
  staging and cross-shard merge) are optional — a depth-0 schedule has
  no prefetch, a single dominance group no reduce — but any that appear
  must carry a numeric ``bytes`` arg, because the serving layer's
  measured-bandwidth EMA is priced from exactly those byte counts.

Checks (bench JSON, ``--bench-json``): top level carries ``bench`` and
a non-empty ``rows`` (operators) or ``configs`` (serve) payload; when
the file is a schema-1 envelope (``benchmarks/schema.py``), its
``metrics`` list must be well-formed (name/value/units/direction, finite
values, no duplicate names).

Checks (Prometheus text, ``--prom``): every non-comment line must parse
as ``name{labels} value`` with a float value; every ``# TYPE`` must be a
known type; and the observability families the calibration/SLO layer
promises (``repro_calibration_*``, ``repro_slo_*``, ``repro_memory_*``)
must all be declared — the exporters emit the headers even with zero
series, so absence means the analysis layer was silently dropped from
the export path.

Usage::

    python tools/validate_trace.py trace.json [--require-phases]
        [--bench-json bench.json] [--prom metrics.prom]
"""

from __future__ import annotations

import argparse
import json
import math
import numbers
import sys

REQUIRED_PHASES = ("h2d", "compute", "d2h")
# optional staging-motion categories; when present, spans must be sized
BYTES_PHASES = ("prefetch", "reduce")

#: families the calibration / SLO / memory analysis layer must export
#: (headers are unconditional, so these must appear in any metrics_text)
REQUIRED_PROM_FAMILIES = (
    "repro_calibration_samples_total",
    "repro_calibration_bias_seconds",
    "repro_calibration_abs_p95_seconds",
    "repro_calibration_drift",
    "repro_memory_modeled_bytes",
    "repro_memory_watermark_bytes",
    "repro_memory_margin_ratio",
    "repro_slo_attainment_ratio",
    "repro_slo_latency_p95_seconds",
    "repro_slo_queue_wait_p95_seconds",
    "repro_slo_completed_total",
)
PROM_TYPES = ("counter", "gauge", "histogram", "summary", "untyped")


def fail(msg: str) -> None:
    print(f"FAIL: {msg}")
    sys.exit(1)


def validate_chrome_trace(path: str, require_phases: bool) -> int:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        fail(f"{path}: top level must be an object with 'traceEvents'")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        fail(f"{path}: 'traceEvents' must be a non-empty list")
    cats = set()
    slab_span_on_device_track = False
    device_tracks = set()
    for e in events:
        for key in ("ph", "name", "pid", "tid"):
            if key not in e:
                fail(f"{path}: event missing {key!r}: {e}")
        if e["ph"] == "M":
            if e["name"] == "thread_name" \
                    and str(e["args"]["name"]).startswith("device"):
                device_tracks.add((e["pid"], e["tid"]))
            continue
        if not isinstance(e.get("ts"), numbers.Real) or e["ts"] < 0:
            fail(f"{path}: event needs numeric ts >= 0: {e}")
        if e["ph"] == "X":
            if not isinstance(e.get("dur"), numbers.Real) or e["dur"] < 0:
                fail(f"{path}: complete event needs dur >= 0: {e}")
            cats.add(e.get("cat"))
            if e.get("cat") in BYTES_PHASES:
                nb = e.get("args", {}).get("bytes")
                if not isinstance(nb, numbers.Real) or nb < 0:
                    fail(f"{path}: {e.get('cat')} span needs a numeric "
                         f"'bytes' arg: {e}")
        elif e["ph"] == "i":
            if "s" not in e:
                fail(f"{path}: instant event needs scope 's': {e}")
    # the device-track check needs the metadata pass above complete
    for e in events:
        if e["ph"] == "X" and "slab" in e.get("args", {}) \
                and (e["pid"], e["tid"]) in device_tracks:
            slab_span_on_device_track = True
            break
    if require_phases:
        missing = [c for c in REQUIRED_PHASES if c not in cats]
        if missing:
            fail(f"{path}: no spans in categories {missing} "
                 f"(saw {sorted(x for x in cats if x)})")
        if not slab_span_on_device_track:
            fail(f"{path}: no per-slab span on a named device track")
    print(f"OK: {path}: {len(events)} events, categories "
          f"{sorted(x for x in cats if x)}, "
          f"{len(device_tracks)} device tracks")
    return len(events)


def _parse_prom_series(line: str):
    """Split ``name{labels} value`` -> (family, value) or raise ValueError."""
    if "{" in line:
        name, rest = line.split("{", 1)
        if "}" not in rest:
            raise ValueError("unterminated label block")
        labels, _, val = rest.rpartition("}")
        for pair in filter(None, labels.split(",")):
            if "=" not in pair or not pair.split("=", 1)[1].startswith('"'):
                raise ValueError(f"malformed label {pair!r}")
    else:
        name, _, val = line.partition(" ")
    name, val = name.strip(), val.strip().split()[0]
    if not name or not name.replace("_", "").replace(":", "").isalnum():
        raise ValueError(f"malformed metric name {name!r}")
    return name, float(val)   # float() raises on garbage; nan/inf are legal


def validate_prometheus(path: str) -> None:
    with open(path) as f:
        text = f.read()
    declared = set()
    n_series = 0
    for i, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 3 and parts[1] in ("HELP", "TYPE"):
                declared.add(parts[2])
                if parts[1] == "TYPE" and (len(parts) < 4 or
                                           parts[3] not in PROM_TYPES):
                    fail(f"{path}:{i}: unknown TYPE in {line!r}")
            continue
        try:
            family, val = _parse_prom_series(line)
        except (ValueError, IndexError) as e:
            fail(f"{path}:{i}: unparseable series {line!r} ({e})")
        if math.isnan(val):
            fail(f"{path}:{i}: NaN sample in {line!r}")
        # a series whose family was never declared is a header regression
        base = family
        for suffix in ("_bucket", "_sum", "_count"):
            if family.endswith(suffix):
                base = family[:-len(suffix)]
        if family not in declared and base not in declared:
            fail(f"{path}:{i}: series {family!r} has no HELP/TYPE header")
        n_series += 1
    missing = [f for f in REQUIRED_PROM_FAMILIES if f not in declared]
    if missing:
        fail(f"{path}: missing observability families {missing}")
    print(f"OK: {path}: {len(declared)} families declared "
          f"({n_series} series), all "
          f"{len(REQUIRED_PROM_FAMILIES)} calibration/SLO/memory "
          f"families present")


def _validate_envelope_metrics(path: str, doc: dict) -> None:
    """Schema-1 envelope checks (beyond the legacy rows/configs ones)."""
    metrics = doc.get("metrics")
    if not isinstance(metrics, list):
        fail(f"{path}: schema envelope needs a 'metrics' list")
    seen = set()
    for m in metrics:
        for key in ("name", "value", "units", "direction"):
            if key not in m:
                fail(f"{path}: metric missing {key!r}: {m}")
        if m["direction"] not in ("higher", "lower"):
            fail(f"{path}: bad metric direction: {m}")
        if not isinstance(m["value"], numbers.Real) \
                or not math.isfinite(m["value"]):
            fail(f"{path}: non-finite metric value: {m}")
        if m["name"] in seen:
            fail(f"{path}: duplicate metric name {m['name']!r}")
        seen.add(m["name"])


def validate_bench_json(path: str) -> None:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "bench" not in doc:
        fail(f"{path}: bench JSON must be an object with 'bench'")
    if doc.get("schema") is not None:
        _validate_envelope_metrics(path, doc)
    rows = doc.get("rows")
    configs = doc.get("configs")
    if rows is not None:
        if not rows:
            fail(f"{path}: empty 'rows'")
        # per-bench row schema: the scaling bench reports overlap-on/off
        # arm times per (op, N, n_dev); the operators bench reports
        # backend x mode operator times
        required = (("op", "N", "n_dev", "overlap_s", "serial_s")
                    if doc["bench"] == "scaling"
                    else ("mode", "backend", "fp_s", "bp_s"))
        for r in rows:
            for key in required:
                if key not in r:
                    fail(f"{path}: row missing {key!r}: {r}")
    elif configs is not None:
        if not configs:
            fail(f"{path}: empty 'configs'")
        for name, s in configs.items():
            if "completed" not in s:
                fail(f"{path}: config {name!r} missing 'completed'")
    else:
        fail(f"{path}: bench JSON needs 'rows' or 'configs'")
    print(f"OK: {path}: bench={doc['bench']!r}")


def main() -> None:
    ap = argparse.ArgumentParser(
        description="validate Chrome-trace / bench JSON artifacts")
    ap.add_argument("trace", help="Chrome-trace JSON to validate")
    ap.add_argument("--require-phases", action="store_true",
                    help="require h2d/compute/d2h spans and a per-slab "
                         "span on a device track (streaming recon traces)")
    ap.add_argument("--bench-json", default="",
                    help="also validate this bench --json output")
    ap.add_argument("--prom", default="",
                    help="also validate this Prometheus text export "
                         "(requires the calibration/SLO/memory families)")
    args = ap.parse_args()
    validate_chrome_trace(args.trace, args.require_phases)
    if args.bench_json:
        validate_bench_json(args.bench_json)
    if args.prom:
        validate_prometheus(args.prom)
    print("TRACE OK")


if __name__ == "__main__":
    main()
